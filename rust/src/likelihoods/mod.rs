//! Response-variable likelihoods `p(y | μ, ξ)` for latent Gaussian
//! process models (paper §3).
//!
//! Each likelihood provides the per-observation log density and its
//! first three derivatives with respect to the latent value `b` (the
//! Laplace approximation needs `W = −∂² log p` and its derivative
//! `∂W/∂b = −∂³ log p`), plus derivatives with respect to auxiliary
//! parameters ξ (Gamma shape, Student-t scale).
//!
//! Link functions follow the paper's experiments: logit for Bernoulli,
//! log for Poisson and Gamma, identity for Student-t.

use crate::kernels::bessel::{digamma, ln_gamma};

/// A single-parameter response likelihood with latent parameter `b`.
#[derive(Clone, Debug, PartialEq)]
pub enum Likelihood {
    /// Gaussian with error variance σ² (used to validate the Laplace path:
    /// Laplace is exact for Gaussian likelihoods).
    Gaussian { variance: f64 },
    /// Bernoulli with logit link: P(y=1) = σ(b).
    BernoulliLogit,
    /// Poisson with log link: y ~ Pois(e^b).
    Poisson,
    /// Gamma with log link and shape α: E[y] = e^b.
    Gamma { shape: f64 },
    /// Student-t with location b, scale s and fixed dof ν.
    StudentT { scale: f64, df: f64 },
}

impl Likelihood {
    /// Number of auxiliary parameters ξ estimated for this likelihood.
    pub fn num_aux(&self) -> usize {
        match self {
            Likelihood::Gaussian { .. } => 1,  // log σ²
            Likelihood::BernoulliLogit => 0,
            Likelihood::Poisson => 0,
            Likelihood::Gamma { .. } => 1,     // log α
            Likelihood::StudentT { .. } => 1,  // log s (df held fixed)
        }
    }

    /// Pack auxiliary parameters as logs.
    pub fn pack_aux(&self) -> Vec<f64> {
        match self {
            Likelihood::Gaussian { variance } => vec![variance.ln()],
            Likelihood::Gamma { shape } => vec![shape.ln()],
            Likelihood::StudentT { scale, .. } => vec![scale.ln()],
            _ => vec![],
        }
    }

    /// Rebuild with new packed auxiliary parameters.
    pub fn with_aux(&self, aux: &[f64]) -> Likelihood {
        match self {
            Likelihood::Gaussian { .. } => Likelihood::Gaussian { variance: aux[0].exp() },
            Likelihood::Gamma { .. } => Likelihood::Gamma { shape: aux[0].exp() },
            Likelihood::StudentT { df, .. } => {
                Likelihood::StudentT { scale: aux[0].exp(), df: *df }
            }
            other => other.clone(),
        }
    }

    /// Log density of one observation.
    pub fn log_density(&self, y: f64, b: f64) -> f64 {
        match *self {
            Likelihood::Gaussian { variance } => {
                let r = y - b;
                -0.5 * ((2.0 * std::f64::consts::PI * variance).ln() + r * r / variance)
            }
            Likelihood::BernoulliLogit => {
                // y ∈ {0, 1}: y·b − log(1 + e^b), numerically stable.
                y * b - softplus(b)
            }
            Likelihood::Poisson => y * b - b.exp() - ln_gamma(y + 1.0),
            Likelihood::Gamma { shape } => {
                shape * (shape.ln() - b) + (shape - 1.0) * y.ln()
                    - shape * y * (-b).exp()
                    - ln_gamma(shape)
            }
            Likelihood::StudentT { scale, df } => {
                let r = (y - b) / scale;
                ln_gamma((df + 1.0) / 2.0)
                    - ln_gamma(df / 2.0)
                    - 0.5 * (df * std::f64::consts::PI).ln()
                    - scale.ln()
                    - 0.5 * (df + 1.0) * (1.0 + r * r / df).ln()
            }
        }
    }

    /// Total log density over a data set.
    pub fn log_density_sum(&self, y: &[f64], b: &[f64]) -> f64 {
        y.iter()
            .zip(b)
            .map(|(yi, bi)| self.log_density(*yi, *bi))
            .sum()
    }

    /// First derivative `∂ log p / ∂b`.
    pub fn d1(&self, y: f64, b: f64) -> f64 {
        match *self {
            Likelihood::Gaussian { variance } => (y - b) / variance,
            Likelihood::BernoulliLogit => y - sigmoid(b),
            Likelihood::Poisson => y - b.exp(),
            Likelihood::Gamma { shape } => -shape + shape * y * (-b).exp(),
            Likelihood::StudentT { scale, df } => {
                let r = y - b;
                (df + 1.0) * r / (df * scale * scale + r * r)
            }
        }
    }

    /// Second derivative `∂² log p / ∂b²` (≤ 0 for log-concave families).
    pub fn d2(&self, y: f64, b: f64) -> f64 {
        match *self {
            Likelihood::Gaussian { variance } => -1.0 / variance,
            Likelihood::BernoulliLogit => {
                let p = sigmoid(b);
                -p * (1.0 - p)
            }
            Likelihood::Poisson => -b.exp(),
            Likelihood::Gamma { shape } => -shape * y * (-b).exp(),
            Likelihood::StudentT { scale, df } => {
                let r = y - b;
                let s2 = df * scale * scale;
                (df + 1.0) * (r * r - s2) / ((s2 + r * r) * (s2 + r * r))
            }
        }
    }

    /// Third derivative `∂³ log p / ∂b³` (for `∂W/∂b` in the Laplace
    /// gradients).
    pub fn d3(&self, y: f64, b: f64) -> f64 {
        match *self {
            Likelihood::Gaussian { .. } => 0.0,
            Likelihood::BernoulliLogit => {
                let p = sigmoid(b);
                -p * (1.0 - p) * (1.0 - 2.0 * p)
            }
            Likelihood::Poisson => -b.exp(),
            Likelihood::Gamma { shape } => shape * y * (-b).exp(),
            Likelihood::StudentT { scale, df } => {
                let r = y - b;
                let s2 = df * scale * scale;
                let den = s2 + r * r;
                // d2(b) = (ν+1)(r²−s2)/den², r = y−b → ∂/∂b = −∂/∂r
                -(df + 1.0) * (2.0 * r * den - (r * r - s2) * 4.0 * r) / (den * den * den)
            }
        }
    }

    /// `W_ii = −∂² log p / ∂b²` (paper Eq. 11), floored for the
    /// non-log-concave Student-t tails (documented deviation: Fisher-style
    /// clamp keeps `W + Σ_†⁻¹` positive definite for iterative solvers).
    pub fn w(&self, y: f64, b: f64) -> f64 {
        (-self.d2(y, b)).max(1e-10)
    }

    /// `∂ log p / ∂ log ξ_l` for the packed auxiliary parameters.
    pub fn d_aux(&self, y: f64, b: f64) -> Vec<f64> {
        match *self {
            Likelihood::Gaussian { variance } => {
                let r = y - b;
                vec![-0.5 + 0.5 * r * r / variance]
            }
            Likelihood::Gamma { shape } => {
                // ∂logp/∂log α = α ∂logp/∂α
                let a = shape;
                vec![a * (a.ln() + 1.0 - b + y.ln() - y * (-b).exp() - digamma(a))]
            }
            Likelihood::StudentT { scale, df } => {
                // ∂logp/∂log s = s ∂/∂s
                let r = (y - b) / scale;
                vec![-1.0 + (df + 1.0) * r * r / (df + r * r)]
            }
            _ => vec![],
        }
    }

    /// `∂² log p / ∂ log ξ_l ∂b` (for the implicit mode derivative).
    pub fn d_aux_db(&self, y: f64, b: f64) -> Vec<f64> {
        match *self {
            Likelihood::Gaussian { variance } => vec![-(y - b) / variance],
            Likelihood::Gamma { shape } => {
                // ∂/∂logα of d1 = α(−1 + y e^{−b})
                vec![shape * (-1.0 + y * (-b).exp())]
            }
            Likelihood::StudentT { scale, df } => {
                // d1 = (ν+1)r/(νs²+r²); ∂/∂log s = s ∂/∂s
                let r = y - b;
                let s2 = df * scale * scale;
                let den = s2 + r * r;
                vec![-(df + 1.0) * r * 2.0 * s2 / (den * den)]
            }
            _ => vec![],
        }
    }

    /// `∂W_ii / ∂ log ξ_l`.
    pub fn d_w_aux(&self, y: f64, b: f64) -> Vec<f64> {
        match *self {
            Likelihood::Gaussian { variance } => vec![-1.0 / variance], // W = 1/σ²
            Likelihood::Gamma { shape } => {
                // W = α y e^{−b}; ∂W/∂log α = W
                vec![shape * y * (-b).exp()]
            }
            Likelihood::StudentT { scale, df } => {
                // W clamped; numeric in log s (simple + matches w()).
                let h = 1e-6;
                let lp = Likelihood::StudentT { scale: scale * (1.0 + h), df };
                let lm = Likelihood::StudentT { scale: scale * (1.0 - h), df };
                vec![(lp.w(y, b) - lm.w(y, b)) / (2.0 * h)]
            }
            _ => vec![],
        }
    }

    /// Predictive response mean given a latent Gaussian `N(mu, var)`:
    /// closed forms where available, else 20-node Gauss–Hermite.
    pub fn predictive_mean(&self, mu: f64, var: f64) -> f64 {
        match *self {
            Likelihood::Gaussian { .. } => mu,
            Likelihood::StudentT { .. } => mu,
            Likelihood::Poisson | Likelihood::Gamma { .. } => (mu + 0.5 * var).exp(),
            Likelihood::BernoulliLogit => gauss_hermite_mean(mu, var, sigmoid),
        }
    }

    /// Predictive response variance given latent `N(mu, var)` (law of
    /// total variance).
    pub fn predictive_var(&self, mu: f64, var: f64) -> f64 {
        match *self {
            Likelihood::Gaussian { variance } => var + variance,
            Likelihood::StudentT { scale, df } => {
                var + if df > 2.0 {
                    scale * scale * df / (df - 2.0)
                } else {
                    f64::INFINITY
                }
            }
            Likelihood::Poisson => {
                let m = (mu + 0.5 * var).exp();
                let e2 = (2.0 * mu + 2.0 * var).exp();
                m + e2 - m * m
            }
            Likelihood::Gamma { shape } => {
                let m = (mu + 0.5 * var).exp();
                let e2 = (2.0 * mu + 2.0 * var).exp();
                e2 * (1.0 + 1.0 / shape) - m * m
            }
            Likelihood::BernoulliLogit => {
                let p = self.predictive_mean(mu, var);
                p * (1.0 - p)
            }
        }
    }

    /// Mean negative predictive log-density (log-score) of observations
    /// given latent Gaussians, by Gauss–Hermite quadrature.
    pub fn log_score(&self, y: &[f64], mu: &[f64], var: &[f64]) -> f64 {
        let n = y.len() as f64;
        y.iter()
            .zip(mu)
            .zip(var)
            .map(|((yi, m), v)| {
                let dens = gauss_hermite_mean(*m, *v, |b| self.log_density(*yi, b).exp());
                -(dens.max(1e-300)).ln()
            })
            .sum::<f64>()
            / n
    }
}

#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[inline]
fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        0.0
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// 20-node Gauss–Hermite expectation `E[f(b)]`, `b ~ N(mu, var)`, with
/// nodes/weights computed at first use from the Jacobi matrix via the
/// library's own symmetric tridiagonal eigensolver (Golub–Welsch).
pub fn gauss_hermite_mean(mu: f64, var: f64, f: impl Fn(f64) -> f64) -> f64 {
    let (nodes, weights) = gh_nodes();
    let s = var.max(0.0).sqrt() * std::f64::consts::SQRT_2;
    let mut acc = 0.0;
    for (x, w) in nodes.iter().zip(weights) {
        acc += w * f(mu + s * x);
    }
    acc / std::f64::consts::PI.sqrt()
}

fn gh_nodes() -> (&'static [f64], &'static [f64]) {
    use std::sync::OnceLock;
    static NODES: OnceLock<(Vec<f64>, Vec<f64>)> = OnceLock::new();
    let nodes = NODES.get_or_init(|| {
        // Golub–Welsch: the Hermite Jacobi matrix has zero diagonal and
        // off-diagonals sqrt(k/2); weights = sqrt(pi)·(first components)².
        let k = 20usize;
        let d = vec![0.0; k];
        let e: Vec<f64> = (1..k).map(|i| (i as f64 / 2.0).sqrt()).collect();
        let t = crate::linalg::SymTridiag::new(d, e);
        let (eigs, first) = crate::linalg::tridiag_eigen(&t);
        let mut pairs: Vec<(f64, f64)> = eigs
            .into_iter()
            .zip(first)
            .map(|(x, w)| (x, std::f64::consts::PI.sqrt() * w * w))
            .collect();
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        (
            pairs.iter().map(|p| p.0).collect(),
            pairs.iter().map(|p| p.1).collect(),
        )
    });
    (&nodes.0, &nodes.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_checks(lik: &Likelihood, y: f64, b: f64) {
        let h = 1e-6;
        let d1_fd = (lik.log_density(y, b + h) - lik.log_density(y, b - h)) / (2.0 * h);
        assert!(
            (lik.d1(y, b) - d1_fd).abs() < 1e-5 * (1.0 + d1_fd.abs()),
            "{lik:?} d1: {} vs {d1_fd}",
            lik.d1(y, b)
        );
        let d2_fd = (lik.d1(y, b + h) - lik.d1(y, b - h)) / (2.0 * h);
        assert!(
            (lik.d2(y, b) - d2_fd).abs() < 1e-5 * (1.0 + d2_fd.abs()),
            "{lik:?} d2: {} vs {d2_fd}",
            lik.d2(y, b)
        );
        let d3_fd = (lik.d2(y, b + h) - lik.d2(y, b - h)) / (2.0 * h);
        assert!(
            (lik.d3(y, b) - d3_fd).abs() < 1e-4 * (1.0 + d3_fd.abs()),
            "{lik:?} d3: {} vs {d3_fd}",
            lik.d3(y, b)
        );
    }

    #[test]
    fn derivative_chains_match_fd() {
        fd_checks(&Likelihood::Gaussian { variance: 0.3 }, 1.2, 0.4);
        fd_checks(&Likelihood::BernoulliLogit, 1.0, 0.7);
        fd_checks(&Likelihood::BernoulliLogit, 0.0, -1.3);
        fd_checks(&Likelihood::Poisson, 3.0, 0.9);
        fd_checks(&Likelihood::Gamma { shape: 2.5 }, 1.7, 0.2);
        fd_checks(&Likelihood::StudentT { scale: 0.8, df: 5.0 }, 2.0, 0.5);
    }

    #[test]
    fn aux_gradients_match_fd() {
        let cases: Vec<(Likelihood, f64, f64)> = vec![
            (Likelihood::Gaussian { variance: 0.4 }, 0.9, 0.2),
            (Likelihood::Gamma { shape: 1.8 }, 2.1, 0.3),
            (Likelihood::StudentT { scale: 0.7, df: 4.0 }, 1.1, -0.2),
        ];
        for (lik, y, b) in cases {
            let aux0 = lik.pack_aux();
            let h = 1e-6;
            let g = lik.d_aux(y, b);
            let g_db = lik.d_aux_db(y, b);
            let g_w = lik.d_w_aux(y, b);
            for l in 0..aux0.len() {
                let mut ap = aux0.clone();
                ap[l] += h;
                let lp = lik.with_aux(&ap);
                let mut am = aux0.clone();
                am[l] -= h;
                let lm = lik.with_aux(&am);
                let fd = (lp.log_density(y, b) - lm.log_density(y, b)) / (2.0 * h);
                assert!(
                    (g[l] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "{lik:?} daux {l}: {} vs {fd}",
                    g[l]
                );
                let fd_db = (lp.d1(y, b) - lm.d1(y, b)) / (2.0 * h);
                assert!(
                    (g_db[l] - fd_db).abs() < 1e-4 * (1.0 + fd_db.abs()),
                    "{lik:?} daux_db {l}: {} vs {fd_db}",
                    g_db[l]
                );
                let fd_w = (lp.w(y, b) - lm.w(y, b)) / (2.0 * h);
                assert!(
                    (g_w[l] - fd_w).abs() < 1e-3 * (1.0 + fd_w.abs()),
                    "{lik:?} dw_aux {l}: {} vs {fd_w}",
                    g_w[l]
                );
            }
        }
    }

    #[test]
    fn gauss_hermite_integrates_polynomials() {
        // E[b²] for N(2, 3) = 4 + 3 = 7.
        let m2 = gauss_hermite_mean(2.0, 3.0, |b| b * b);
        assert!((m2 - 7.0).abs() < 1e-8, "{m2}");
        // E[e^b] for N(0.5, 0.8) = exp(0.9)
        let me = gauss_hermite_mean(0.5, 0.8, f64::exp);
        assert!((me - (0.9f64).exp()).abs() < 1e-6, "{me}");
    }

    #[test]
    fn bernoulli_predictive_mean_bounds() {
        let lik = Likelihood::BernoulliLogit;
        let p = lik.predictive_mean(1.0, 2.0);
        assert!(p > 0.5 && p < sigmoid(1.0));
        let p0 = lik.predictive_mean(1.0, 0.0);
        assert!((p0 - sigmoid(1.0)).abs() < 1e-8);
    }

    #[test]
    fn poisson_predictive_moments() {
        let lik = Likelihood::Poisson;
        let (mu, var) = (0.7, 0.4);
        let m = lik.predictive_mean(mu, var);
        assert!((m - (0.9f64).exp()).abs() < 1e-10);
        assert!(lik.predictive_var(mu, var) > m); // overdispersed
    }

    #[test]
    fn gaussian_log_score_matches_closed_form() {
        let lik = Likelihood::Gaussian { variance: 0.3 };
        let got = lik.log_score(&[1.0], &[0.5], &[0.2]);
        // y ~ N(0.5, 0.5) → -log N(1.0; 0.5, 0.5)
        let want = 0.5 * ((2.0 * std::f64::consts::PI * 0.5f64).ln() + 0.25 / 0.5);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn digamma_reference() {
        // ψ(1) = −γ
        assert!((digamma(1.0) + 0.5772156649015329).abs() < 1e-10);
        // ψ(0.5) = −γ − 2 ln 2
        assert!((digamma(0.5) + 0.5772156649015329 + 2.0 * (2.0f64).ln()).abs() < 1e-9);
    }
}
