//! Special functions for general-smoothness Matérn kernels (§8.3):
//! log-gamma (Lanczos) and the modified Bessel function of the second
//! kind `K_ν(x)` for fractional order (Temme's method + upward
//! recurrence, cf. Numerical Recipes `besselik`).

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Gamma function.
pub fn gamma(x: f64) -> f64 {
    if x > 0.0 {
        ln_gamma(x).exp()
    } else {
        let pi = std::f64::consts::PI;
        pi / ((pi * x).sin() * ln_gamma(1.0 - x).exp())
    }
}

/// Digamma function ψ(x) (asymptotic series + downward recurrence),
/// needed for Gamma-likelihood shape-parameter gradients.
pub fn digamma(x: f64) -> f64 {
    assert!(x > 0.0, "digamma domain x={x}");
    let mut x = x;
    let mut acc = 0.0;
    // Recurrence ψ(x) = ψ(x+1) − 1/x until x large enough for asymptotics.
    while x < 10.0 {
        acc -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    // Asymptotic expansion ψ(x) ≈ ln x − 1/(2x) − Σ B_{2n}/(2n x^{2n})
    acc + x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 * (1.0 / 240.0))))
}

/// Chebyshev-series helper for Temme's Γ coefficients.
fn chebev(a: f64, b: f64, c: &[f64], x: f64) -> f64 {
    let y = (2.0 * x - a - b) / (b - a);
    let y2 = 2.0 * y;
    let (mut d, mut dd) = (0.0, 0.0);
    for &cj in c.iter().rev().take(c.len() - 1) {
        let sv = d;
        d = y2 * d - dd + cj;
        dd = sv;
    }
    y * d - dd + 0.5 * c[0]
}

const C1: [f64; 7] = [
    -1.142022680371168e0,
    6.5165112670737e-3,
    3.087090173086e-4,
    -3.4706269649e-6,
    6.9437664e-9,
    3.67795e-11,
    -1.356e-13,
];
const C2: [f64; 8] = [
    1.843740587300905e0,
    -7.68528408447867e-2,
    1.2719271366546e-3,
    -4.9717367042e-6,
    -3.31261198e-8,
    2.423096e-10,
    -1.702e-13,
    -1.49e-15,
];

/// Temme's gam1, gam2, gampl, gammi for |x| <= 1/2.
fn beschb(x: f64) -> (f64, f64, f64, f64) {
    let xx = 8.0 * x * x - 1.0;
    let gam1 = chebev(-1.0, 1.0, &C1, xx);
    let gam2 = chebev(-1.0, 1.0, &C2, xx);
    let gampl = gam2 - x * gam1;
    let gammi = gam2 + x * gam1;
    (gam1, gam2, gampl, gammi)
}

/// Modified Bessel function of the second kind `K_ν(x)` for `ν ≥ 0`,
/// `x > 0`. Accuracy ~1e-10 relative over the ranges a Matérn kernel
/// evaluates (x up to ~700 before underflow).
pub fn bessel_k(nu: f64, x: f64) -> f64 {
    assert!(x > 0.0 && nu >= 0.0, "bessel_k domain: nu={nu} x={x}");
    const MAXIT: usize = 10_000;
    const XMIN: f64 = 2.0;
    let nl = (nu + 0.5).floor() as i64; // number of upward recurrences
    let xmu = nu - nl as f64; // |xmu| <= 1/2
    let xmu2 = xmu * xmu;
    let xi = 1.0 / x;
    let xi2 = 2.0 * xi;

    let (mut rkmu, mut rk1);
    if x < XMIN {
        // Temme's series.
        let x2 = 0.5 * x;
        let pimu = std::f64::consts::PI * xmu;
        let fact = if pimu.abs() < f64::EPSILON {
            1.0
        } else {
            pimu / pimu.sin()
        };
        let mut d = -x2.ln();
        let e = xmu * d;
        let fact2 = if e.abs() < f64::EPSILON {
            1.0
        } else {
            e.sinh() / e
        };
        let (gam1, gam2, gampl, gammi) = beschb(xmu);
        let mut ff = fact * (gam1 * e.cosh() + gam2 * fact2 * d);
        let mut sum = ff;
        let e = e.exp();
        let mut p = 0.5 * e / gampl;
        let mut q = 0.5 / (e * gammi);
        let mut c = 1.0;
        d = x2 * x2;
        let mut sum1 = p;
        let mut converged = false;
        for i in 1..=MAXIT {
            let fi = i as f64;
            ff = (fi * ff + p + q) / (fi * fi - xmu2);
            c *= d / fi;
            p /= fi - xmu;
            q /= fi + xmu;
            let del = c * ff;
            sum += del;
            let del1 = c * (p - fi * ff);
            sum1 += del1;
            if del.abs() < sum.abs() * f64::EPSILON {
                converged = true;
                break;
            }
        }
        assert!(converged, "bessel_k series failed to converge");
        rkmu = sum;
        rk1 = sum1 * xi2;
    } else {
        // Steed/Temme continued fraction CF2.
        let mut b = 2.0 * (1.0 + x);
        let mut d = 1.0 / b;
        let mut h = d;
        let mut delh = d;
        let mut q1 = 0.0;
        let mut q2 = 1.0;
        let a1 = 0.25 - xmu2;
        let mut q = a1;
        let mut c = a1;
        let mut a = -a1;
        let mut s = 1.0 + q * delh;
        let mut converged = false;
        for i in 2..=MAXIT {
            let fi = i as f64;
            a -= 2.0 * (fi - 1.0);
            c = -a * c / fi;
            let qnew = (q1 - b * q2) / a;
            q1 = q2;
            q2 = qnew;
            q += c * qnew;
            b += 2.0;
            d = 1.0 / (b + a * d);
            delh = (b * d - 1.0) * delh;
            h += delh;
            let dels = q * delh;
            s += dels;
            // The CF stalls at ~1e-15 relative; 1e-14 is ample for kernel use.
            if (dels / s).abs() < 1e-14 {
                converged = true;
                break;
            }
        }
        assert!(converged, "bessel_k CF2 failed to converge");
        let h = a1 * h;
        rkmu = (std::f64::consts::PI / (2.0 * x)).sqrt() * (-x).exp() / s;
        rk1 = rkmu * (xmu + x + 0.5 - h) * xi;
    }
    // Upward recurrence to order nu.
    let mut xmu_cur = xmu;
    for _ in 0..nl {
        let rktemp = (xmu_cur + 1.0) * xi2 * rk1 + rkmu;
        rkmu = rk1;
        rk1 = rktemp;
        xmu_cur += 1.0;
    }
    rkmu
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from scipy.special.kv
    #[test]
    fn k_half_closed_form() {
        // K_{1/2}(x) = sqrt(pi/(2x)) e^{-x}
        for &x in &[0.1, 0.5, 1.0, 3.0, 10.0] {
            let expect = (std::f64::consts::PI / (2.0 * x)).sqrt() * (-x as f64).exp();
            let got = bessel_k(0.5, x);
            assert!(
                ((got - expect) / expect).abs() < 1e-9,
                "x={x} got={got} expect={expect}"
            );
        }
    }

    #[test]
    fn k_three_halves_closed_form() {
        // K_{3/2}(x) = sqrt(pi/(2x)) e^{-x} (1 + 1/x)
        for &x in &[0.2, 1.0, 4.0] {
            let expect =
                (std::f64::consts::PI / (2.0 * x)).sqrt() * (-x as f64).exp() * (1.0 + 1.0 / x);
            let got = bessel_k(1.5, x);
            assert!(((got - expect) / expect).abs() < 1e-9, "x={x}");
        }
    }

    #[test]
    fn k_zero_and_one_reference() {
        // scipy: kv(0, 1.0) = 0.42102443824070834
        assert!((bessel_k(0.0, 1.0) - 0.42102443824070834).abs() < 1e-10);
        // scipy: kv(1, 1.0) = 0.6019072301972346
        assert!((bessel_k(1.0, 1.0) - 0.6019072301972346).abs() < 1e-10);
        // scipy: kv(0, 5.0) = 0.003691098334042594
        assert!((bessel_k(0.0, 5.0) - 0.003691098334042594).abs() < 1e-12);
    }

    #[test]
    fn k_fractional_reference() {
        // scipy: kv(0.3, 0.7) = 0.6895624897569778
        let got = bessel_k(0.3, 0.7);
        assert!((got - 0.6895624897569778).abs() < 1e-9, "got={got}");
        // scipy: kv(2.7, 3.1) = 0.08398615546654484
        let got = bessel_k(2.7, 3.1);
        assert!(((got - 0.08398615546654484) / 0.08398615546654484).abs() < 1e-8, "got={got}");
    }

    #[test]
    fn ln_gamma_reference() {
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - (24.0f64).ln()).abs() < 1e-12);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-12);
    }
}
