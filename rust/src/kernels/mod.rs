//! ARD Matérn covariance functions with analytic gradients.
//!
//! The paper (§2, §6, §7) works with automatic-relevance-determination
//! (ARD) Matérn kernels
//!
//! ```text
//! c_θ(s, s') = σ₁² · k_ν(r),    r = ‖q_λ(s) − q_λ(s')‖,
//! q_λ(s) = (s₁/λ₁, …, s_d/λ_d)
//! ```
//!
//! with smoothness ν ∈ {1/2, 3/2, 5/2, ∞(Gaussian)} in closed form plus a
//! general-ν path via the modified Bessel function `K_ν` (used for the
//! §8.3 smoothness-estimation experiments).
//!
//! Gradients are taken with respect to *log*-parameters (log σ₁²,
//! log λ₁…λ_d, log ν), matching how the optimizer parameterizes the model.
//!
//! # Panel evaluation
//!
//! Besides the per-pair entry points (`cov`, `cov_and_grad_into`), the
//! kernel exposes *panel* kernels that evaluate one query point against a
//! gathered, row-major `len×d` panel of points in a single fused pass:
//! [`ArdMatern::corr_panel`] / [`ArdMatern::cov_panel`] accumulate the
//! scaled distances for the whole panel and then apply `corr_of_dist`
//! over the contiguous slice, and [`ArdMatern::cov_and_grad_panel`]
//! additionally produces every log-parameter gradient from **one**
//! shared `dcorr_dr` pass (the per-dimension length-scale gradients all
//! reuse the same `σ₁² k'(r)/r` factor). These back the panelized
//! residual-covariance assembly in `vecchia`/`vif` (`rho_block`,
//! `rho_and_grad_block`) and the cover-tree batched metric
//! (`covertree::Metric::dist_batch`), replacing the scalar per-pair hot
//! loops of `ResidualFactor::build`, the Appendix-A gradient pass, and
//! the correlation kNN search.
//!
//! # Lane backend
//!
//! The panel evaluators dispatch onto 4-lane kernels
//! ([`crate::linalg::simd`]) when the panel work (`len × d` entries·dims,
//! `q² × d` for symmetric blocks) reaches
//! [`crate::linalg::simd::SIMD_MIN_WORK`] and `VIFGP_SIMD` ≠ `0`:
//! [`ArdMatern::scaled_dist_panel`] precomputes inverse length scales
//! (multiply instead of divide in the inner loop), accumulates four
//! panel rows' r² chains per pass (unroll-and-jam), and batches the
//! square roots into one contiguous vectorizable sweep; the
//! length-scale gradient pass of [`ArdMatern::cov_and_grad_panel`]
//! applies the same 4-row unrolling to the shared-`dcorr_dr` fusion.
//! `cross_cov_into` / `sym_cov_into` are routed through the panel
//! primitives row-wise (a row-major `Mat` is its own panel), so the
//! dense covariance blocks — and `runtime::cross_cov_panel_into`'s
//! native path — inherit the dispatch. The per-entry scalar loops stay
//! as `*_scalar` oracles with `*_simd` pinning the lane path; SIMD ≡
//! scalar ≤1e-12 is enforced by `rust/tests/simd.rs`, and below the
//! threshold both backends are bit-identical (the scalar path runs).

pub mod bessel;

use crate::linalg::{simd, Mat};
use bessel::{bessel_k, ln_gamma};

/// Matérn smoothness parameter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Smoothness {
    /// ν = 1/2 (exponential kernel)
    Half,
    /// ν = 3/2
    ThreeHalves,
    /// ν = 5/2
    FiveHalves,
    /// ν = ∞ (Gaussian / squared-exponential kernel)
    Gaussian,
    /// General fractional ν > 0, evaluated via Bessel K_ν.
    General(f64),
}

impl Smoothness {
    /// Numeric ν (`f64::INFINITY` for the Gaussian kernel).
    pub fn nu(&self) -> f64 {
        match *self {
            Smoothness::Half => 0.5,
            Smoothness::ThreeHalves => 1.5,
            Smoothness::FiveHalves => 2.5,
            Smoothness::Gaussian => f64::INFINITY,
            Smoothness::General(v) => v,
        }
    }

    /// Canonicalize `General` values that hit a closed form.
    pub fn canonical(v: f64) -> Smoothness {
        if (v - 0.5).abs() < 1e-12 {
            Smoothness::Half
        } else if (v - 1.5).abs() < 1e-12 {
            Smoothness::ThreeHalves
        } else if (v - 2.5).abs() < 1e-12 {
            Smoothness::FiveHalves
        } else if v.is_infinite() {
            Smoothness::Gaussian
        } else {
            Smoothness::General(v)
        }
    }

    pub fn parse(s: &str) -> Option<Smoothness> {
        match s {
            "0.5" | "half" | "exp" | "matern12" => Some(Smoothness::Half),
            "1.5" | "matern32" => Some(Smoothness::ThreeHalves),
            "2.5" | "matern52" => Some(Smoothness::FiveHalves),
            "inf" | "gaussian" | "rbf" | "sqexp" => Some(Smoothness::Gaussian),
            other => other.parse::<f64>().ok().map(Smoothness::canonical),
        }
    }
}

/// An ARD Matérn covariance function `c_θ`.
#[derive(Clone, Debug)]
pub struct ArdMatern {
    /// Marginal (signal) variance σ₁².
    pub variance: f64,
    /// Per-dimension length scales λ₁…λ_d.
    pub length_scales: Vec<f64>,
    /// Matérn smoothness ν.
    pub smoothness: Smoothness,
}

/// Alias used throughout the library: the single covariance family the
/// paper's experiments use.
pub type CovFunction = ArdMatern;

impl ArdMatern {
    pub fn new(variance: f64, length_scales: Vec<f64>, smoothness: Smoothness) -> Self {
        assert!(variance > 0.0);
        assert!(length_scales.iter().all(|&l| l > 0.0));
        ArdMatern { variance, length_scales, smoothness }
    }

    /// Isotropic shorthand: one shared length scale across `d` dimensions.
    pub fn isotropic(variance: f64, length_scale: f64, d: usize, smoothness: Smoothness) -> Self {
        Self::new(variance, vec![length_scale; d], smoothness)
    }

    pub fn dim(&self) -> usize {
        self.length_scales.len()
    }

    /// Number of covariance parameters (σ₁² + d length scales).
    pub fn num_params(&self) -> usize {
        1 + self.dim()
    }

    /// Scaled distance r between two points.
    #[inline]
    pub fn scaled_dist(&self, a: &[f64], b: &[f64]) -> f64 {
        let mut s = 0.0;
        for ((&x, &y), &l) in a.iter().zip(b).zip(&self.length_scales) {
            let u = (x - y) / l;
            s += u * u;
        }
        s.sqrt()
    }

    /// Radial profile `k_ν(r)` with k(0)=1 (correlation form, σ₁² applied
    /// by the caller).
    #[inline]
    pub fn corr_of_dist(&self, r: f64) -> f64 {
        match self.smoothness {
            Smoothness::Half => (-r).exp(),
            Smoothness::ThreeHalves => {
                let t = SQRT3 * r;
                (1.0 + t) * (-t).exp()
            }
            Smoothness::FiveHalves => {
                let t = SQRT5 * r;
                (1.0 + t + t * t / 3.0) * (-t).exp()
            }
            Smoothness::Gaussian => (-0.5 * r * r).exp(),
            Smoothness::General(nu) => matern_general(nu, r),
        }
    }

    /// Derivative `d k_ν / d r` of the radial profile.
    #[inline]
    pub fn dcorr_dr(&self, r: f64) -> f64 {
        match self.smoothness {
            Smoothness::Half => -(-r).exp(),
            Smoothness::ThreeHalves => -3.0 * r * (-SQRT3 * r).exp(),
            Smoothness::FiveHalves => {
                let t = SQRT5 * r;
                -(5.0 / 3.0) * r * (1.0 + t) * (-t).exp()
            }
            Smoothness::Gaussian => -r * (-0.5 * r * r).exp(),
            Smoothness::General(nu) => {
                // d/dr [ 2^{1-ν}/Γ(ν) (√(2ν)r)^ν K_ν(√(2ν)r) ]
                //   = -2^{1-ν}/Γ(ν) √(2ν) (√(2ν)r)^ν K_{ν-1}(√(2ν)r)
                // using K_ν'(x) = -(K_{ν-1}+K_{ν+1})/2 and the recurrence.
                if r <= 0.0 {
                    return 0.0;
                }
                let s = (2.0 * nu).sqrt();
                let x = s * r;
                let c = (2.0f64.ln() * (1.0 - nu) - ln_gamma(nu)).exp();
                -c * s * x.powf(nu) * bessel_k((nu - 1.0).abs(), x)
            }
        }
    }

    /// Covariance between two points.
    #[inline]
    pub fn cov(&self, a: &[f64], b: &[f64]) -> f64 {
        self.variance * self.corr_of_dist(self.scaled_dist(a, b))
    }

    /// Scaled distances `r_t = ‖q_λ(q) − q_λ(panel_t)‖` of one query
    /// point against a gathered row-major `len×d` panel (`len =
    /// out.len()`). Fused accumulation over the contiguous panel rows —
    /// the building block of the panel kernels below. Dispatches onto
    /// the lane backend above the work threshold (`len·d`).
    pub fn scaled_dist_panel(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        if simd::use_simd(out.len() * self.dim()) {
            self.scaled_dist_panel_simd(q, panel, out)
        } else {
            self.scaled_dist_panel_scalar(q, panel, out)
        }
    }

    /// Scalar oracle for [`scaled_dist_panel`](Self::scaled_dist_panel):
    /// per-entry divide-and-accumulate with an in-loop square root.
    pub fn scaled_dist_panel_scalar(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        let d = self.dim();
        let len = out.len();
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(panel.len(), len * d);
        for (t, r) in out.iter_mut().enumerate() {
            let row = &panel[t * d..(t + 1) * d];
            let mut s = 0.0;
            for j in 0..d {
                let u = (q[j] - row[j]) / self.length_scales[j];
                s += u * u;
            }
            *r = s.sqrt();
        }
    }

    /// Lane-backend [`scaled_dist_panel`](Self::scaled_dist_panel):
    /// inverse length scales precomputed (multiply, not divide, in the
    /// inner loop), four panel rows' r² chains accumulated per pass,
    /// and the square roots batched into one contiguous sweep.
    pub fn scaled_dist_panel_simd(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        let d = self.dim();
        let len = out.len();
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(panel.len(), len * d);
        let mut inv_stack = [0.0f64; 16];
        let inv_heap: Vec<f64>;
        let il: &[f64] = if d <= inv_stack.len() {
            for (s, &l) in inv_stack.iter_mut().zip(&self.length_scales) {
                *s = 1.0 / l;
            }
            &inv_stack[..d]
        } else {
            inv_heap = self.length_scales.iter().map(|l| 1.0 / l).collect();
            &inv_heap
        };
        let t4 = len - len % 4;
        let mut t0 = 0;
        while t0 < t4 {
            let p0 = &panel[t0 * d..(t0 + 1) * d];
            let p1 = &panel[(t0 + 1) * d..(t0 + 2) * d];
            let p2 = &panel[(t0 + 2) * d..(t0 + 3) * d];
            let p3 = &panel[(t0 + 3) * d..(t0 + 4) * d];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for j in 0..d {
                let qj = q[j];
                let ij = il[j];
                let u0 = (qj - p0[j]) * ij;
                let u1 = (qj - p1[j]) * ij;
                let u2 = (qj - p2[j]) * ij;
                let u3 = (qj - p3[j]) * ij;
                s0 += u0 * u0;
                s1 += u1 * u1;
                s2 += u2 * u2;
                s3 += u3 * u3;
            }
            out[t0] = s0;
            out[t0 + 1] = s1;
            out[t0 + 2] = s2;
            out[t0 + 3] = s3;
            t0 += 4;
        }
        for (t, r) in out.iter_mut().enumerate().take(len).skip(t4) {
            let row = &panel[t * d..(t + 1) * d];
            let mut s = 0.0;
            for j in 0..d {
                let u = (q[j] - row[j]) * il[j];
                s += u * u;
            }
            *r = s;
        }
        for r in out.iter_mut() {
            *r = r.sqrt();
        }
    }

    /// Correlations `k_ν(r_t)` (σ₁² **not** applied) of one query point
    /// against a gathered `len×d` panel: one scaled-distance pass, then
    /// the radial profile over the contiguous slice.
    pub fn corr_panel(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        self.corr_panel_impl(q, panel, out, simd::use_simd(out.len() * self.dim()))
    }

    /// [`corr_panel`](Self::corr_panel) pinned to the scalar oracle.
    pub fn corr_panel_scalar(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        self.corr_panel_impl(q, panel, out, false)
    }

    /// [`corr_panel`](Self::corr_panel) pinned to the lane backend. The
    /// fault-injection hook fires on this path exactly like the scalar
    /// one (`rust/tests/simd.rs` asserts it).
    pub fn corr_panel_simd(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        self.corr_panel_impl(q, panel, out, true)
    }

    fn corr_panel_impl(&self, q: &[f64], panel: &[f64], out: &mut [f64], use_lanes: bool) {
        if use_lanes {
            self.scaled_dist_panel_simd(q, panel, out);
        } else {
            self.scaled_dist_panel_scalar(q, panel, out);
        }
        for r in out.iter_mut() {
            *r = self.corr_of_dist(*r);
        }
        // Chaos hook: one relaxed atomic load when faults are disarmed.
        // Shared by both backends — the NaN-panel fault surface does not
        // depend on the dispatch decision.
        crate::faults::poison_panel(out);
    }

    /// Covariances `σ₁² k_ν(r_t)` of one query point against a gathered
    /// `len×d` panel.
    pub fn cov_panel(&self, q: &[f64], panel: &[f64], out: &mut [f64]) {
        self.cov_panel_impl(q, panel, out, simd::use_simd(out.len() * self.dim()))
    }

    fn cov_panel_impl(&self, q: &[f64], panel: &[f64], out: &mut [f64], use_lanes: bool) {
        self.corr_panel_impl(q, panel, out, use_lanes);
        for c in out.iter_mut() {
            *c *= self.variance;
        }
    }

    /// Symmetric covariance block of the `q` points of a gathered
    /// row-major `q×d` panel (`q = out.rows()`): the strictly-lower
    /// triangle is evaluated row-by-row via [`cov_panel`](Self::cov_panel)
    /// against the panel prefix, the diagonal is `σ₁²`, and the lower
    /// triangle is mirrored. This is the kernel part of the prediction
    /// pipeline's `ρ_NN` conditioning blocks (`vif::predict`), which
    /// reads each point's pre-gathered neighbor panel straight from the
    /// frozen `PredictPlan`.
    pub fn sym_cov_panel(&self, panel: &[f64], out: &mut Mat) {
        // One dispatch decision for the whole block (q²·d/2 entry·dims
        // of work across the triangle).
        let q = out.rows();
        self.sym_cov_panel_impl(panel, out, simd::use_simd(q * q * self.dim() / 2))
    }

    /// [`sym_cov_panel`](Self::sym_cov_panel) pinned to the scalar oracle.
    pub fn sym_cov_panel_scalar(&self, panel: &[f64], out: &mut Mat) {
        self.sym_cov_panel_impl(panel, out, false)
    }

    /// [`sym_cov_panel`](Self::sym_cov_panel) pinned to the lane backend.
    pub fn sym_cov_panel_simd(&self, panel: &[f64], out: &mut Mat) {
        self.sym_cov_panel_impl(panel, out, true)
    }

    fn sym_cov_panel_impl(&self, panel: &[f64], out: &mut Mat, use_lanes: bool) {
        let d = self.dim();
        let q = out.rows();
        debug_assert_eq!(out.cols(), q, "sym_cov_panel output not square");
        debug_assert_eq!(panel.len(), q * d, "sym_cov_panel panel shape");
        for a in 0..q {
            let row = out.row_mut(a);
            self.cov_panel_impl(
                &panel[a * d..(a + 1) * d],
                &panel[..a * d],
                &mut row[..a],
                use_lanes,
            );
            row[a] = self.variance;
        }
        // Mirror the strictly-lower triangle, reading each source row as
        // one contiguous slice instead of per-element get/set.
        let data = out.data_mut();
        for a in 1..q {
            let (upper, lower) = data.split_at_mut(a * q);
            for (b, &v) in lower[..a].iter().enumerate() {
                upper[b * q + a] = v;
            }
        }
    }

    /// Covariances **and** all `1 + d` log-parameter gradients of one
    /// query point against a gathered `len×d` panel. `grad` holds the
    /// per-parameter blocks contiguously: `grad[p·len + t] =
    /// ∂c(q, panel_t)/∂θ_p` with `p = 0` the log-σ₁² slot and `p = 1+j`
    /// the log-λ_j slots. One `dcorr_dr` evaluation per panel entry is
    /// shared across all `d` length-scale gradients (the scalar path
    /// pays the same evaluation per pair but through a virtual call and
    /// strided writes).
    pub fn cov_and_grad_panel(&self, q: &[f64], panel: &[f64], cov: &mut [f64], grad: &mut [f64]) {
        self.cov_and_grad_panel_impl(q, panel, cov, grad, simd::use_simd(cov.len() * self.dim()))
    }

    /// [`cov_and_grad_panel`](Self::cov_and_grad_panel) pinned to the
    /// scalar oracle.
    pub fn cov_and_grad_panel_scalar(
        &self,
        q: &[f64],
        panel: &[f64],
        cov: &mut [f64],
        grad: &mut [f64],
    ) {
        self.cov_and_grad_panel_impl(q, panel, cov, grad, false)
    }

    /// [`cov_and_grad_panel`](Self::cov_and_grad_panel) pinned to the
    /// lane backend.
    pub fn cov_and_grad_panel_simd(
        &self,
        q: &[f64],
        panel: &[f64],
        cov: &mut [f64],
        grad: &mut [f64],
    ) {
        self.cov_and_grad_panel_impl(q, panel, cov, grad, true)
    }

    fn cov_and_grad_panel_impl(
        &self,
        q: &[f64],
        panel: &[f64],
        cov: &mut [f64],
        grad: &mut [f64],
        use_lanes: bool,
    ) {
        let d = self.dim();
        let len = cov.len();
        debug_assert_eq!(q.len(), d);
        debug_assert_eq!(panel.len(), len * d);
        debug_assert_eq!(grad.len(), (1 + d) * len);
        if use_lanes {
            self.scaled_dist_panel_simd(q, panel, cov); // cov holds r_t for now
        } else {
            self.scaled_dist_panel_scalar(q, panel, cov);
        }
        let (gsig, glen) = grad.split_at_mut(len);
        // Stash the shared factor s_t = σ₁² k'(r_t)/r_t in the log-σ₁²
        // block while the length-scale blocks are filled, then overwrite
        // it with the final ∂c/∂log σ₁² = c.
        for t in 0..len {
            let r = cov[t];
            gsig[t] = if r > 0.0 {
                self.variance * self.dcorr_dr(r) / r
            } else {
                0.0
            };
            cov[t] = self.variance * self.corr_of_dist(r);
        }
        if use_lanes {
            // Lane path for the dcorr_dr-fused length-scale pass: inverse
            // scale multiply plus four panel rows' u_j² chains per pass
            // (the panel is row-major, so `panel[t*d + j]` strides by `d`
            // — unroll-and-jam over `t` keeps four independent chains in
            // flight per stride).
            let t4 = len - len % 4;
            for (j, (&lj, &qj)) in self.length_scales.iter().zip(q).enumerate() {
                let gj = &mut glen[j * len..(j + 1) * len];
                let ij = 1.0 / lj;
                let mut t0 = 0;
                while t0 < t4 {
                    let u0 = (qj - panel[t0 * d + j]) * ij;
                    let u1 = (qj - panel[(t0 + 1) * d + j]) * ij;
                    let u2 = (qj - panel[(t0 + 2) * d + j]) * ij;
                    let u3 = (qj - panel[(t0 + 3) * d + j]) * ij;
                    gj[t0] = -gsig[t0] * u0 * u0;
                    gj[t0 + 1] = -gsig[t0 + 1] * u1 * u1;
                    gj[t0 + 2] = -gsig[t0 + 2] * u2 * u2;
                    gj[t0 + 3] = -gsig[t0 + 3] * u3 * u3;
                    t0 += 4;
                }
                for (t, g) in gj.iter_mut().enumerate().take(len).skip(t4) {
                    let u = (qj - panel[t * d + j]) * ij;
                    *g = -gsig[t] * u * u;
                }
            }
        } else {
            for (j, (&lj, &qj)) in self.length_scales.iter().zip(q).enumerate() {
                let gj = &mut glen[j * len..(j + 1) * len];
                for (t, g) in gj.iter_mut().enumerate() {
                    // ∂c/∂log λ_j = −(σ₁² k'(r)/r) u_j²
                    let u = (qj - panel[t * d + j]) / lj;
                    *g = -gsig[t] * u * u;
                }
            }
        }
        gsig.copy_from_slice(cov);
    }

    /// Cross-covariance matrix `[c_θ(a_i, b_j)]` (rows over `a`).
    pub fn cross_cov(&self, a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.rows());
        self.cross_cov_into(a, b, &mut out);
        out
    }

    /// [`cross_cov`](Self::cross_cov) writing into a preallocated
    /// `a.rows() × b.rows()` output (the θ-refresh path reuses panels).
    /// Routed row-wise through [`scaled_dist_panel`](Self::scaled_dist_panel)
    /// — a row-major `Mat` is its own `len×d` panel — so the dense
    /// covariance blocks inherit the lane-backend dispatch. Deliberately
    /// does **not** pass through `corr_panel`: the fault-injection
    /// NaN-panel hook is scoped to the gathered-panel evaluators.
    pub fn cross_cov_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(out.rows(), a.rows(), "cross_cov_into row mismatch");
        assert_eq!(out.cols(), b.rows(), "cross_cov_into col mismatch");
        assert_eq!(a.cols(), self.dim(), "cross_cov_into dim mismatch");
        assert_eq!(b.cols(), self.dim(), "cross_cov_into dim mismatch");
        for i in 0..a.rows() {
            let orow = out.row_mut(i);
            self.scaled_dist_panel(a.row(i), b.data(), orow);
            for v in orow.iter_mut() {
                *v = self.variance * self.corr_of_dist(*v);
            }
        }
    }

    /// Symmetric covariance matrix over one point set, with optional nugget.
    pub fn sym_cov(&self, a: &Mat, nugget: f64) -> Mat {
        let n = a.rows();
        let mut out = Mat::zeros(n, n);
        self.sym_cov_into(a, nugget, &mut out);
        out
    }

    /// [`sym_cov`](Self::sym_cov) writing into a preallocated `n × n`
    /// output. Every entry is overwritten. The strictly-lower triangle
    /// is evaluated row-wise via
    /// [`scaled_dist_panel`](Self::scaled_dist_panel) against the point
    /// set's row-major prefix (inheriting the lane-backend dispatch),
    /// then mirrored with row-slice reads.
    pub fn sym_cov_into(&self, a: &Mat, nugget: f64, out: &mut Mat) {
        let n = a.rows();
        let d = self.dim();
        assert_eq!(out.rows(), n, "sym_cov_into row mismatch");
        assert_eq!(out.cols(), n, "sym_cov_into col mismatch");
        assert_eq!(a.cols(), d, "sym_cov_into dim mismatch");
        for i in 0..n {
            let row = out.row_mut(i);
            self.scaled_dist_panel(a.row(i), &a.data()[..i * d], &mut row[..i]);
            for v in row[..i].iter_mut() {
                *v = self.variance * self.corr_of_dist(*v);
            }
            row[i] = self.variance + nugget;
        }
        let data = out.data_mut();
        for i in 1..n {
            let (upper, lower) = data.split_at_mut(i * n);
            for (j, &v) in lower[..i].iter().enumerate() {
                upper[j * n + i] = v;
            }
        }
    }

    /// Covariance and its gradient wrt `[log σ₁², log λ₁…λ_d]`
    /// evaluated at a single pair. Returns `(cov, grad)`.
    pub fn cov_and_grad(&self, a: &[f64], b: &[f64]) -> (f64, Vec<f64>) {
        let mut grad = vec![0.0; 1 + self.dim()];
        let c = self.cov_and_grad_into(a, b, &mut grad);
        (c, grad)
    }

    /// Allocation-free [`Self::cov_and_grad`] — the inner loop of the
    /// Appendix-A gradient pass calls this millions of times (§Perf).
    /// `grad` must have length `1 + d`; returns the covariance.
    #[inline]
    pub fn cov_and_grad_into(&self, a: &[f64], b: &[f64], grad: &mut [f64]) -> f64 {
        let d = self.dim();
        debug_assert_eq!(grad.len(), 1 + d);
        let mut r2 = 0.0;
        for j in 0..d {
            let u = (a[j] - b[j]) / self.length_scales[j];
            r2 += u * u;
        }
        let r = r2.sqrt();
        let k = self.corr_of_dist(r);
        let c = self.variance * k;
        grad[0] = c; // ∂c/∂log σ₁² = c
        if r > 0.0 {
            let dkdr_over_r = self.variance * self.dcorr_dr(r) / r;
            for j in 0..d {
                // ∂r/∂log λ_j = −u_j²/r
                let u = (a[j] - b[j]) / self.length_scales[j];
                grad[1 + j] = -dkdr_over_r * u * u;
            }
        } else {
            grad[1..].iter_mut().for_each(|g| *g = 0.0);
        }
        c
    }

    /// Gradient of a full cross-covariance matrix wrt log-parameter `p`
    /// (0 = log σ₁², 1+j = log λ_j).
    pub fn cross_cov_grad(&self, a: &Mat, b: &Mat, p: usize) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.rows());
        for i in 0..a.rows() {
            let ra = a.row(i);
            for j in 0..b.rows() {
                let (_, g) = self.cov_and_grad(ra, b.row(j));
                out.set(i, j, g[p]);
            }
        }
        out
    }

    /// Gradient of the symmetric covariance matrix wrt log-parameter `p`.
    pub fn sym_cov_grad(&self, a: &Mat, p: usize) -> Mat {
        let n = a.rows();
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            if p == 0 {
                out.set(i, i, self.variance);
            }
            for j in 0..i {
                let (_, g) = self.cov_and_grad(a.row(i), a.row(j));
                out.set(i, j, g[p]);
                out.set(j, i, g[p]);
            }
        }
        out
    }

    /// Pack `[log σ₁², log λ…]` (the optimizer's view of this kernel).
    pub fn log_params(&self) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.num_params());
        p.push(self.variance.ln());
        p.extend(self.length_scales.iter().map(|l| l.ln()));
        p
    }

    /// Rebuild from packed log-parameters.
    pub fn from_log_params(p: &[f64], smoothness: Smoothness) -> Self {
        assert!(p.len() >= 2);
        ArdMatern::new(
            p[0].exp(),
            p[1..].iter().map(|x| x.exp()).collect(),
            smoothness,
        )
    }
}

const SQRT3: f64 = 1.7320508075688772;
const SQRT5: f64 = 2.23606797749979;

/// General-ν Matérn correlation `2^{1-ν}/Γ(ν) (√(2ν)r)^ν K_ν(√(2ν)r)`.
fn matern_general(nu: f64, r: f64) -> f64 {
    if r <= 1e-14 {
        return 1.0;
    }
    let x = (2.0 * nu).sqrt() * r;
    if x > 700.0 {
        return 0.0; // underflow guard
    }
    let lg = 2.0f64.ln() * (1.0 - nu) - ln_gamma(nu) + nu * x.ln();
    let k = bessel_k(nu, x);
    if k <= 0.0 {
        return 0.0;
    }
    (lg + k.ln()).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kern(s: Smoothness) -> ArdMatern {
        ArdMatern::new(1.7, vec![0.4, 0.9, 1.3], s)
    }

    #[test]
    fn cov_at_zero_distance_is_variance() {
        for s in [
            Smoothness::Half,
            Smoothness::ThreeHalves,
            Smoothness::FiveHalves,
            Smoothness::Gaussian,
            Smoothness::General(0.8),
        ] {
            let k = kern(s);
            let p = [0.3, -0.2, 0.5];
            assert!((k.cov(&p, &p) - 1.7).abs() < 1e-10, "{s:?}");
        }
    }

    #[test]
    fn general_matches_closed_forms() {
        // General(1/2 ± 0) should agree with the closed forms.
        for (nu, closed) in [
            (0.5, Smoothness::Half),
            (1.5, Smoothness::ThreeHalves),
            (2.5, Smoothness::FiveHalves),
        ] {
            let kg = ArdMatern::new(1.0, vec![0.7, 0.7], Smoothness::General(nu));
            let kc = ArdMatern::new(1.0, vec![0.7, 0.7], closed);
            for t in 1..10 {
                let a = [0.0, 0.0];
                let b = [0.1 * t as f64, 0.05 * t as f64];
                let (g, c) = (kg.cov(&a, &b), kc.cov(&a, &b));
                assert!(
                    (g - c).abs() < 1e-8,
                    "nu={nu} t={t} general={g} closed={c}"
                );
            }
        }
    }

    #[test]
    fn monotone_decreasing_in_distance() {
        for s in [
            Smoothness::Half,
            Smoothness::ThreeHalves,
            Smoothness::FiveHalves,
            Smoothness::Gaussian,
            Smoothness::General(3.7),
        ] {
            let k = kern(s);
            let mut last = f64::INFINITY;
            for t in 0..20 {
                let v = k.corr_of_dist(0.2 * t as f64);
                assert!(v <= last + 1e-12, "{s:?}");
                last = v;
            }
        }
    }

    #[test]
    fn dcorr_dr_matches_finite_difference() {
        for s in [
            Smoothness::Half,
            Smoothness::ThreeHalves,
            Smoothness::FiveHalves,
            Smoothness::Gaussian,
            Smoothness::General(1.9),
        ] {
            let k = kern(s);
            for t in 1..8 {
                let r = 0.3 * t as f64;
                let h = 1e-6;
                let fd = (k.corr_of_dist(r + h) - k.corr_of_dist(r - h)) / (2.0 * h);
                let an = k.dcorr_dr(r);
                assert!(
                    (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                    "{s:?} r={r} fd={fd} an={an}"
                );
            }
        }
    }

    #[test]
    fn param_gradients_match_finite_difference() {
        for s in [Smoothness::ThreeHalves, Smoothness::Gaussian, Smoothness::General(0.9)] {
            let k = kern(s);
            let a = [0.3, 0.1, -0.4];
            let b = [-0.2, 0.6, 0.2];
            let (_, grad) = k.cov_and_grad(&a, &b);
            let p0 = k.log_params();
            for pi in 0..p0.len() {
                let h = 1e-6;
                let mut pp = p0.clone();
                pp[pi] += h;
                let kp = ArdMatern::from_log_params(&pp, s);
                let mut pm = p0.clone();
                pm[pi] -= h;
                let km = ArdMatern::from_log_params(&pm, s);
                let fd = (kp.cov(&a, &b) - km.cov(&a, &b)) / (2.0 * h);
                assert!(
                    (fd - grad[pi]).abs() < 1e-5 * (1.0 + grad[pi].abs()),
                    "{s:?} param {pi}: fd={fd} an={}",
                    grad[pi]
                );
            }
        }
    }

    #[test]
    fn cross_cov_shapes_and_symmetry() {
        let k = kern(Smoothness::ThreeHalves);
        let a = Mat::from_fn(4, 3, |i, j| (i as f64) * 0.1 + (j as f64) * 0.05);
        let b = Mat::from_fn(6, 3, |i, j| (i as f64) * 0.07 - (j as f64) * 0.02);
        let c = k.cross_cov(&a, &b);
        assert_eq!((c.rows(), c.cols()), (4, 6));
        let s = k.sym_cov(&a, 0.01);
        for i in 0..4 {
            assert!((s.get(i, i) - (1.7 + 0.01)).abs() < 1e-12);
            for j in 0..4 {
                assert!((s.get(i, j) - s.get(j, i)).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn log_param_round_trip() {
        let k = kern(Smoothness::FiveHalves);
        let p = k.log_params();
        let k2 = ArdMatern::from_log_params(&p, Smoothness::FiveHalves);
        assert!((k.variance - k2.variance).abs() < 1e-12);
        for (a, b) in k.length_scales.iter().zip(&k2.length_scales) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn panel_matches_per_pair() {
        for s in [
            Smoothness::Half,
            Smoothness::ThreeHalves,
            Smoothness::FiveHalves,
            Smoothness::Gaussian,
            Smoothness::General(1.3),
        ] {
            let k = kern(s);
            let q = [0.25, -0.4, 0.6];
            // 6-point panel, including an exact duplicate of the query
            // (r = 0) to cover the zero-distance gradient branch.
            let mut panel = Vec::new();
            for t in 0..6 {
                if t == 3 {
                    panel.extend_from_slice(&q);
                } else {
                    panel.extend_from_slice(&[
                        0.1 * t as f64,
                        -0.05 * t as f64 + 0.2,
                        0.3 - 0.07 * t as f64,
                    ]);
                }
            }
            let mut covs = vec![0.0; 6];
            k.cov_panel(&q, &panel, &mut covs);
            let mut corrs = vec![0.0; 6];
            k.corr_panel(&q, &panel, &mut corrs);
            let mut covs2 = vec![0.0; 6];
            let mut grads = vec![0.0; 4 * 6];
            k.cov_and_grad_panel(&q, &panel, &mut covs2, &mut grads);
            let mut g = vec![0.0; 4];
            for t in 0..6 {
                let b = &panel[t * 3..(t + 1) * 3];
                let c = k.cov_and_grad_into(&q, b, &mut g);
                assert!((covs[t] - c).abs() < 1e-14, "{s:?} cov t={t}");
                assert!((corrs[t] - c / k.variance).abs() < 1e-14, "{s:?} corr t={t}");
                assert!((covs2[t] - c).abs() < 1e-14, "{s:?} cov+grad t={t}");
                for p in 0..4 {
                    assert!(
                        (grads[p * 6 + t] - g[p]).abs() < 1e-14,
                        "{s:?} grad p={p} t={t}: {} vs {}",
                        grads[p * 6 + t],
                        g[p]
                    );
                }
            }
        }
    }

    #[test]
    fn panel_empty_and_single() {
        let k = kern(Smoothness::ThreeHalves);
        let q = [0.1, 0.2, 0.3];
        let mut out: Vec<f64> = vec![];
        k.cov_panel(&q, &[], &mut out); // no-op, must not panic
        let panel = [0.4, 0.5, 0.6];
        let mut c = vec![0.0; 1];
        let mut g = vec![0.0; 4];
        k.cov_and_grad_panel(&q, &panel, &mut c, &mut g);
        let (want, wg) = k.cov_and_grad(&q, &panel);
        assert!((c[0] - want).abs() < 1e-14);
        for p in 0..4 {
            assert!((g[p] - wg[p]).abs() < 1e-14);
        }
    }

    #[test]
    fn smoothness_parse() {
        assert_eq!(Smoothness::parse("1.5"), Some(Smoothness::ThreeHalves));
        assert_eq!(Smoothness::parse("gaussian"), Some(Smoothness::Gaussian));
        assert_eq!(Smoothness::parse("0.7"), Some(Smoothness::General(0.7)));
        assert_eq!(Smoothness::parse("bogus"), None);
    }
}
