//! Optimizers for marginal-likelihood minimization.
//!
//! The paper trains with (limited-memory) BFGS on log-parameters; this
//! module implements L-BFGS with a backtracking Armijo line search plus
//! Adam and a 1-D golden-section search (used for the Matérn smoothness
//! ν in §8.3).

use crate::linalg::dot;

/// Result of an optimization run.
#[derive(Clone, Debug)]
pub struct OptResult {
    pub x: Vec<f64>,
    pub value: f64,
    pub iters: usize,
    /// Objective value after each accepted step.
    pub trace: Vec<f64>,
    pub converged: bool,
}

/// L-BFGS (history 8) minimizing `f`, which returns `(value, gradient)`.
/// Stops when the gradient inf-norm falls below `tol` or after
/// `max_iters` accepted steps.
pub fn lbfgs(
    f: &dyn Fn(&[f64]) -> (f64, Vec<f64>),
    x0: &[f64],
    max_iters: usize,
    tol: f64,
) -> OptResult {
    const M: usize = 8;
    let mut x = x0.to_vec();
    let (mut fx, mut g) = f(&x);
    let mut trace = vec![fx];
    let mut s_hist: Vec<Vec<f64>> = Vec::new();
    let mut y_hist: Vec<Vec<f64>> = Vec::new();
    let mut rho_hist: Vec<f64> = Vec::new();
    let mut converged = false;
    let mut iters = 0;

    for _ in 0..max_iters {
        if inf_norm(&g) < tol {
            converged = true;
            break;
        }
        // Two-loop recursion for d = −H g.
        let mut q = g.clone();
        let k = s_hist.len();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            alpha[i] = rho_hist[i] * dot(&s_hist[i], &q);
            for (qj, yj) in q.iter_mut().zip(&y_hist[i]) {
                *qj -= alpha[i] * yj;
            }
        }
        // Initial scaling γ = sᵀy / yᵀy.
        let gamma = if k > 0 {
            let sy = dot(&s_hist[k - 1], &y_hist[k - 1]);
            let yy = dot(&y_hist[k - 1], &y_hist[k - 1]);
            (sy / yy).max(1e-8)
        } else {
            1.0 / inf_norm(&g).max(1.0)
        };
        for qj in q.iter_mut() {
            *qj *= gamma;
        }
        for i in 0..k {
            let beta = rho_hist[i] * dot(&y_hist[i], &q);
            for (qj, sj) in q.iter_mut().zip(&s_hist[i]) {
                *qj += (alpha[i] - beta) * sj;
            }
        }
        let d: Vec<f64> = q.iter().map(|v| -v).collect();
        let dir_deriv = dot(&g, &d);
        // Ensure descent; otherwise restart with steepest descent.
        let (d, dir_deriv) = if dir_deriv < 0.0 {
            (d, dir_deriv)
        } else {
            s_hist.clear();
            y_hist.clear();
            rho_hist.clear();
            let d: Vec<f64> = g.iter().map(|v| -v).collect();
            let dd = -dot(&g, &g);
            (d, dd)
        };
        // Backtracking Armijo line search with max-step clamp (log-params:
        // steps > ~2 in log space explode kernels).
        let max_step = 2.0 / inf_norm(&d).max(1e-12);
        let mut t = max_step.min(1.0);
        let c1 = 1e-4;
        let mut accepted = false;
        for _ in 0..30 {
            let xt: Vec<f64> = x.iter().zip(&d).map(|(xi, di)| xi + t * di).collect();
            let (ft, gt) = f(&xt);
            if ft.is_finite() && ft <= fx + c1 * t * dir_deriv {
                // Update history.
                let s_vec: Vec<f64> = xt.iter().zip(&x).map(|(a, b)| a - b).collect();
                let y_vec: Vec<f64> = gt.iter().zip(&g).map(|(a, b)| a - b).collect();
                let sy = dot(&s_vec, &y_vec);
                if sy > 1e-10 * dot(&y_vec, &y_vec).max(1e-300) {
                    if s_hist.len() == M {
                        s_hist.remove(0);
                        y_hist.remove(0);
                        rho_hist.remove(0);
                    }
                    rho_hist.push(1.0 / sy);
                    s_hist.push(s_vec);
                    y_hist.push(y_vec);
                }
                x = xt;
                fx = ft;
                g = gt;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        iters += 1;
        if !accepted {
            converged = true; // line search exhausted: local flatness
            break;
        }
        trace.push(fx);
        let len = trace.len();
        if len >= 2 && (trace[len - 2] - fx).abs() < 1e-9 * (1.0 + fx.abs()) {
            converged = true;
            break;
        }
    }
    OptResult { x, value: fx, iters, trace, converged }
}

/// Adam (for stochastic objectives where L-BFGS line searches are
/// unreliable, e.g. SLQ-noised likelihoods).
pub fn adam(
    f: &dyn Fn(&[f64]) -> (f64, Vec<f64>),
    x0: &[f64],
    lr: f64,
    max_iters: usize,
    tol: f64,
) -> OptResult {
    let n = x0.len();
    let mut x = x0.to_vec();
    let (mut fx, mut g) = f(&x);
    let mut trace = vec![fx];
    let (b1, b2, eps) = (0.9, 0.999, 1e-8);
    let mut m = vec![0.0; n];
    let mut v = vec![0.0; n];
    let mut converged = false;
    let mut iters = 0;
    let mut best = x.clone();
    let mut best_f = fx;
    for t in 1..=max_iters {
        if inf_norm(&g) < tol {
            converged = true;
            break;
        }
        for i in 0..n {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
            let mh = m[i] / (1.0 - b1f(t, b1));
            let vh = v[i] / (1.0 - b1f(t, b2));
            x[i] -= lr * mh / (vh.sqrt() + eps);
        }
        let (ft, gt) = f(&x);
        fx = ft;
        g = gt;
        trace.push(fx);
        if fx < best_f {
            best_f = fx;
            best = x.clone();
        }
        iters = t;
    }
    OptResult { x: best, value: best_f, iters, trace, converged }
}

fn b1f(t: usize, b: f64) -> f64 {
    b.powi(t as i32)
}

/// Golden-section minimization of a univariate function on `[lo, hi]`.
pub fn golden_section(f: &dyn Fn(f64) -> f64, lo: f64, hi: f64, iters: usize) -> (f64, f64) {
    let phi = (5.0f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut b) = (lo, hi);
    let mut c = b - phi * (b - a);
    let mut d = a + phi * (b - a);
    let (mut fc, mut fd) = (f(c), f(d));
    for _ in 0..iters {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - phi * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + phi * (b - a);
            fd = f(d);
        }
    }
    if fc < fd {
        (c, fc)
    } else {
        (d, fd)
    }
}

fn inf_norm(v: &[f64]) -> f64 {
    v.iter().fold(0.0, |a, b| a.max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rosenbrock(x: &[f64]) -> (f64, Vec<f64>) {
        let (a, b) = (1.0, 100.0);
        let f = (a - x[0]).powi(2) + b * (x[1] - x[0] * x[0]).powi(2);
        let g = vec![
            -2.0 * (a - x[0]) - 4.0 * b * x[0] * (x[1] - x[0] * x[0]),
            2.0 * b * (x[1] - x[0] * x[0]),
        ];
        (f, g)
    }

    #[test]
    fn lbfgs_solves_rosenbrock() {
        let res = lbfgs(&rosenbrock, &[-1.2, 1.0], 500, 1e-8);
        assert!(res.value < 1e-10, "value {}", res.value);
        assert!((res.x[0] - 1.0).abs() < 1e-4 && (res.x[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn lbfgs_quadratic_fast() {
        let f = |x: &[f64]| -> (f64, Vec<f64>) {
            let v = x.iter().enumerate().map(|(i, xi)| (i + 1) as f64 * xi * xi).sum::<f64>();
            let g = x.iter().enumerate().map(|(i, xi)| 2.0 * (i + 1) as f64 * xi).collect();
            (v, g)
        };
        let res = lbfgs(&f, &[3.0, -2.0, 1.0, 5.0], 100, 1e-10);
        assert!(res.value < 1e-12);
        assert!(res.converged);
    }

    #[test]
    fn trace_is_monotone_for_lbfgs() {
        let res = lbfgs(&rosenbrock, &[0.5, 0.5], 200, 1e-8);
        for w in res.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn adam_decreases_quadratic() {
        let f = |x: &[f64]| -> (f64, Vec<f64>) {
            (x[0] * x[0] + x[1] * x[1], vec![2.0 * x[0], 2.0 * x[1]])
        };
        let res = adam(&f, &[2.0, -3.0], 0.1, 300, 1e-8);
        assert!(res.value < 1e-3, "value {}", res.value);
    }

    #[test]
    fn golden_section_finds_minimum() {
        let f = |x: f64| (x - 2.7).powi(2) + 1.0;
        let (xm, fm) = golden_section(&f, 0.0, 5.0, 60);
        assert!((xm - 2.7).abs() < 1e-6);
        assert!((fm - 1.0).abs() < 1e-10);
    }
}
