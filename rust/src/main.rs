//! `vifgp` — command-line entry point for the VIF Gaussian-process
//! library (Layer-3 leader binary).
//!
//! Subcommands (hand-rolled parser; no clap in the offline registry):
//!
//! ```text
//! vifgp info
//! vifgp simulate --n 5000 --d 2 [--smoothness 1.5] [--likelihood gaussian]
//!                [--seed 0] --out data.csv
//! vifgp train    --data data.csv [--m 200] [--mv 30] [--smoothness 1.5]
//!                [--likelihood gaussian|bernoulli|poisson|gamma|student_t]
//!                [--precond fitc|vifdu|none] [--iters 50] [--test-frac 0.2]
//! vifgp serve    --data data.csv [--m 200] [--mv 30] [--iters 30]
//!                [--requests 4096] [--concurrency 8] [--append-every 0]
//!                [--max-batch 64] [--batch-window-us 200]
//! vifgp experiment <fig2|fig4|tab1|...>   (thin wrappers over the benches)
//! ```
//!
//! Flag parsing lives in [`vifgp::cli`] so its contract is testable: a
//! malformed value (numeric flags, `--likelihood`, `--smoothness`,
//! `--test-frac` bounds) exits 2 with an error naming the flag, the
//! offending value, and the expected type — never a silent default.

use std::collections::HashMap;
use std::sync::Arc;

use vifgp::cli::{flag, parse_flags, parse_likelihood, parse_smoothness, validate_test_frac};
use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::ArdMatern;
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::serve::{ServeEngine, ServeModel, ServeOptions};
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

/// Unwrap a `cli` parse result or exit 2 with the error on stderr.
macro_rules! require {
    ($e:expr) => {
        match $e {
            Ok(v) => v,
            Err(msg) => {
                eprintln!("{msg}");
                return 2;
            }
        }
    };
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        usage();
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..]);
    if let Err(msg) = apply_runtime_flags(&flags) {
        eprintln!("{msg}");
        std::process::exit(2);
    }
    // Arm deterministic fault injection from `--faults` / `VIFGP_FAULTS`
    // (chaos testing only; a malformed spec panics loudly, crate policy).
    vifgp::faults::init_from_env();
    // Resolve the dense-kernel backend and the warm-start mode up front
    // so a malformed `VIFGP_SIMD` / `VIFGP_WARM_START` fails loudly at
    // startup, not mid-fit (crate policy).
    vifgp::linalg::simd::simd_enabled();
    vifgp::vif::warm_start_enabled();
    let code = match cmd.as_str() {
        "info" => cmd_info(),
        "simulate" => cmd_simulate(&flags),
        "train" => cmd_train(&flags),
        "serve" => cmd_serve(&flags),
        "experiment" => cmd_experiment(&args[1..]),
        "help" | "--help" | "-h" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown command `{other}`");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "vifgp — Vecchia-inducing-points full-scale GP approximations
USAGE:
  vifgp info
  vifgp simulate --n N --d D [--smoothness S] [--likelihood L] [--seed K] --out FILE
  vifgp train --data FILE [--m M] [--mv MV] [--smoothness S] [--likelihood L]
              [--precond fitc|vifdu|none] [--iters I] [--test-frac F] [--seed K]
  vifgp serve --data FILE [--m M] [--mv MV] [--smoothness S] [--likelihood L]
              [--iters I] [--test-frac F] [--seed K] [--requests N]
              [--concurrency C] [--append-every A] [--max-batch B]
              [--batch-window-us W]
  vifgp experiment NAME   (see rust/benches/ for the table/figure harnesses)
GLOBAL FLAGS (any command):
  --threads N           worker-pool size (default: detected parallelism;
                        same as VIFGP_THREADS)
  --sched-threshold N   min rows before Vecchia B sweeps use the level-
                        scheduled parallel path (0 = always; default 2048;
                        same as VIFGP_SCHED_THRESHOLD)
  --warm-start 0|1      fit-trajectory warm starts: 1 (default) carries
                        solver state across L-BFGS evaluations, 0 runs the
                        cold oracle path (same as VIFGP_WARM_START)
  --faults SPEC         deterministic fault injection for chaos testing
                        (same as VIFGP_FAULTS; never use in production)"
    );
}

/// Apply the global `--threads` / `--sched-threshold` flags by setting
/// the corresponding environment variables before the worker pool or any
/// residual factor is created.
fn apply_runtime_flags(flags: &HashMap<String, String>) -> Result<(), String> {
    if let Some(t) = flags.get("threads") {
        match t.parse::<usize>() {
            Ok(v) if v >= 1 => std::env::set_var("VIFGP_THREADS", v.to_string()),
            _ => return Err(format!("--threads expects a positive integer, got `{t}`")),
        }
    }
    if let Some(t) = flags.get("sched-threshold") {
        match t.parse::<usize>() {
            Ok(v) => std::env::set_var("VIFGP_SCHED_THRESHOLD", v.to_string()),
            _ => {
                return Err(format!(
                    "--sched-threshold expects a non-negative integer, got `{t}`"
                ))
            }
        }
    }
    if let Some(t) = flags.get("warm-start") {
        match t.as_str() {
            "0" | "1" => std::env::set_var("VIFGP_WARM_START", t),
            _ => {
                return Err(format!(
                    "--warm-start expects `0` (cold oracle) or `1` (warm-started), got `{t}`"
                ))
            }
        }
    }
    if let Some(spec) = flags.get("faults") {
        // Equivalent to VIFGP_FAULTS=SPEC; parsed (and loudly rejected
        // if malformed) by `faults::init_from_env` right after this.
        std::env::set_var("VIFGP_FAULTS", spec);
    }
    Ok(())
}

fn init_runtime() {
    let dir = vifgp::runtime::default_artifact_dir();
    if vifgp::runtime::init_from_artifacts(&dir) {
        eprintln!("[vifgp] PJRT engine loaded from {dir:?}");
    }
}

fn cmd_info() -> i32 {
    println!("vifgp {} — three-layer Rust + JAX + Pallas VIF GP library", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", vifgp::coordinator::num_threads());
    println!(
        "dense kernels: {}",
        if vifgp::linalg::simd::simd_enabled() {
            "SIMD lane backend (f64x4, register-blocked; VIFGP_SIMD=0 for scalar)"
        } else {
            "scalar oracle (VIFGP_SIMD=0)"
        }
    );
    let dir = vifgp::runtime::default_artifact_dir();
    if vifgp::runtime::init_from_artifacts(&dir) {
        let e = vifgp::runtime::engine().unwrap();
        let m = e.manifest();
        println!(
            "PJRT engine: loaded ({:?}; panel {}x{} d_pad {} tile {}x{})",
            dir, m.panel_n, m.panel_m, m.d_pad, m.tile_n, m.tile_m
        );
    } else {
        println!("PJRT engine: unavailable (run `make artifacts`); native covariance path");
    }
    0
}

fn cmd_simulate(flags: &HashMap<String, String>) -> i32 {
    let n: usize = require!(flag(flags, "n", 5000));
    let d: usize = require!(flag(flags, "d", 2));
    let seed: u64 = require!(flag(flags, "seed", 0));
    let smoothness = require!(parse_smoothness(flags));
    let lik = require!(parse_likelihood(flags));
    let Some(out) = flags.get("out") else {
        eprintln!("--out FILE required");
        return 2;
    };
    let mut rng = Rng::seed_from(seed);
    let x = data::uniform_inputs(&mut rng, n, d);
    let kernel = ArdMatern::new(1.0, data::paper_length_scales(d, smoothness), smoothness);
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y = data::simulate_response(&mut rng, &latent, &lik);
    match data::save_csv(std::path::Path::new(out), &x, &y) {
        Ok(()) => {
            println!("wrote {n}×{d} (+response) to {out}");
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn cmd_train(flags: &HashMap<String, String>) -> i32 {
    // Validate the whole flag surface before touching the filesystem, so
    // a malformed flag is always the exit-2 error the user sees.
    let seed: u64 = require!(flag(flags, "seed", 0));
    let test_frac: f64 = require!(flag(flags, "test-frac", 0.2).and_then(validate_test_frac));
    let m: usize = require!(flag(flags, "m", 200));
    let mv: usize = require!(flag(flags, "mv", 30));
    let iters: usize = require!(flag(flags, "iters", 50));
    let smoothness = require!(parse_smoothness(flags));
    let lik = require!(parse_likelihood(flags));
    let precond_name = flags.get("precond").map(|s| s.as_str()).unwrap_or("fitc");
    let Some(precond) = PrecondType::parse(precond_name) else {
        eprintln!(
            "unknown --precond `{precond_name}`; valid names (case-insensitive): {}",
            PrecondType::VALID_NAMES.join(", ")
        );
        return 2;
    };
    let Some(path) = flags.get("data") else {
        eprintln!("--data FILE required");
        return 2;
    };
    init_runtime();
    let (x, y) = match data::load_csv(std::path::Path::new(path)) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("load failed: {e}");
            return 1;
        }
    };
    let n = x.rows();
    let d = x.cols();

    let mut rng = Rng::seed_from(seed);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (tr, te) = data::train_test_split(&mut rng, n, n_test);
    let (xtr, ytr) = (data::subset_rows(&x, &tr), data::subset_vec(&y, &tr));
    let (xte, yte) = (data::subset_rows(&x, &te), data::subset_vec(&y, &te));
    println!("loaded {n}×{d}; train {} / test {}", tr.len(), te.len());

    let config = VifConfig {
        smoothness,
        num_inducing: m.min(xtr.rows()),
        num_neighbors: mv,
        selection: NeighborSelection::CorrelationCoverTree,
        seed,
        ..Default::default()
    };
    let init_kernel = ArdMatern::isotropic(1.0, 0.5, d, smoothness);
    let t0 = std::time::Instant::now();
    match lik {
        Likelihood::Gaussian { .. } => {
            let init = GaussianParams { kernel: init_kernel, noise: 0.2 };
            let mut model = match VifRegression::try_new(xtr, ytr, config, init) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("invalid training data: {e}");
                    return 2;
                }
            };
            let nll = model.fit(iters);
            println!("fit done in {:.1}s  NLL {:.3}", t0.elapsed().as_secs_f64(), nll);
            println!(
                "  σ₁² {:.4}  σ² {:.4}  λ {:?}",
                model.params.kernel.variance,
                model.params.noise,
                model
                    .params
                    .kernel
                    .length_scales
                    .iter()
                    .map(|l| (l * 1e4).round() / 1e4)
                    .collect::<Vec<_>>()
            );
            if !yte.is_empty() {
                let (mean, var) = model.predict(&xte);
                println!(
                    "  test RMSE {:.4}  LS {:.4}  CRPS {:.4}",
                    metrics::rmse(&mean, &yte),
                    metrics::log_score_gaussian(&mean, &var, &yte),
                    metrics::crps_gaussian(&mean, &var, &yte)
                );
            }
        }
        _ => {
            let mode = SolveMode::Iterative(IterConfig {
                precond,
                seed,
                ..Default::default()
            });
            let mut model =
                match VifLaplaceModel::try_new(xtr, ytr, config, mode, init_kernel, lik.clone()) {
                    Ok(m) => m,
                    Err(e) => {
                        eprintln!("invalid training data: {e}");
                        return 2;
                    }
                };
            let nll = model.fit(iters);
            println!("fit done in {:.1}s  L^VIFLA {:.3}", t0.elapsed().as_secs_f64(), nll);
            println!(
                "  σ₁² {:.4}  λ {:?}  ξ {:?}",
                model.kernel.variance,
                model
                    .kernel
                    .length_scales
                    .iter()
                    .map(|l| (l * 1e4).round() / 1e4)
                    .collect::<Vec<_>>(),
                model.lik.pack_aux().iter().map(|a| a.exp()).collect::<Vec<_>>()
            );
            if !yte.is_empty() {
                let pred = model.predict(&xte, PredVarMethod::Sbpv, 100);
                match lik {
                    Likelihood::BernoulliLogit => {
                        let labels: Vec<bool> = yte.iter().map(|&v| v > 0.5).collect();
                        println!(
                            "  test AUC {:.4}  ACC {:.4}  Brier-RMSE {:.4}",
                            metrics::auc(&pred.response_mean, &labels),
                            metrics::accuracy(&pred.response_mean, &labels),
                            metrics::brier_rmse(&pred.response_mean, &labels)
                        );
                    }
                    _ => {
                        println!(
                            "  test RMSE {:.4}  LS {:.4}",
                            metrics::rmse(&pred.response_mean, &yte),
                            model.lik.log_score(&yte, &pred.latent_mean, &pred.latent_var)
                        );
                    }
                }
            }
        }
    }
    let stats = vifgp::iterative::solve_stats().snapshot();
    if stats.cg_iters > 0 || stats.warm_hits > 0 || stats.warm_misses > 0 {
        println!(
            "  solver: {} CG iterations, warm-start {} hits / {} misses ({})",
            stats.cg_iters,
            stats.warm_hits,
            stats.warm_misses,
            if vifgp::vif::warm_start_enabled() { "warm" } else { "cold oracle" }
        );
    }
    if stats.failures() > 0 || stats.chol_jitter_escalations > 0 || stats.nonfinite_evals > 0 {
        println!(
            "  containment: {} solve failures ({} retries / {} recovered / {} dense fallbacks / \
             {} unrecovered), {} jittered factorizations, {} sanitized evals",
            stats.failures(),
            stats.retries,
            stats.retry_successes,
            stats.dense_fallbacks,
            stats.unrecovered,
            stats.chol_jitter_escalations,
            stats.nonfinite_evals
        );
    }
    0
}

/// `vifgp serve`: fit a model, freeze a serving snapshot, and drive the
/// concurrent engine with an in-process load generator — `--concurrency`
/// client threads issuing `--requests` point queries total, optionally
/// with a writer ingesting `--append-batch` points every
/// `--append-every` requests and publishing the new generation under
/// traffic. Prints the p50/p99 latency and points/sec report; writes it
/// to `VIFGP_SERVE_METRICS_JSON` when set.
fn cmd_serve(flags: &HashMap<String, String>) -> i32 {
    // Flags and env knobs first (exit 2 / loud panic), filesystem second.
    let seed: u64 = require!(flag(flags, "seed", 0));
    let test_frac: f64 = require!(flag(flags, "test-frac", 0.2).and_then(validate_test_frac));
    let m: usize = require!(flag(flags, "m", 200));
    let mv: usize = require!(flag(flags, "mv", 30));
    let iters: usize = require!(flag(flags, "iters", 30));
    let requests: usize = require!(flag(flags, "requests", 4096));
    let concurrency: usize = require!(flag(flags, "concurrency", 8));
    let append_every: usize = require!(flag(flags, "append-every", 0));
    let append_batch: usize = require!(flag(flags, "append-batch", 16));
    let smoothness = require!(parse_smoothness(flags));
    let lik = require!(parse_likelihood(flags));
    if concurrency == 0 {
        eprintln!("--concurrency expects a positive integer, got `0`");
        return 2;
    }
    let mut opts = ServeOptions::from_env();
    if flags.contains_key("max-batch") {
        opts.max_batch = require!(flag(flags, "max-batch", opts.max_batch));
        if opts.max_batch == 0 {
            eprintln!("--max-batch expects a positive integer, got `0`");
            return 2;
        }
    }
    if flags.contains_key("batch-window-us") {
        let us: u64 = require!(flag(flags, "batch-window-us", 200));
        opts.batch_window = std::time::Duration::from_micros(us);
    }
    let Some(path) = flags.get("data") else {
        eprintln!("--data FILE required");
        return 2;
    };
    init_runtime();
    let (x, y) = match data::load_csv(std::path::Path::new(path)) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("load failed: {e}");
            return 1;
        }
    };
    let n = x.rows();
    let d = x.cols();

    let mut rng = Rng::seed_from(seed);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (tr, te) = data::train_test_split(&mut rng, n, n_test);
    let (xtr, ytr) = (data::subset_rows(&x, &tr), data::subset_vec(&y, &tr));
    let (xte, yte) = (data::subset_rows(&x, &te), data::subset_vec(&y, &te));
    println!("loaded {n}×{d}; train {} / query pool {}", tr.len(), te.len());
    // Query pool: held-out rows, or resampled training rows when the
    // split leaves none. The writer's ingest stream reuses the pool too.
    let (qpool, qresp) = if te.is_empty() { (xtr.clone(), ytr.clone()) } else { (xte, yte) };

    let config = VifConfig {
        smoothness,
        num_inducing: m.min(xtr.rows()),
        num_neighbors: mv,
        selection: NeighborSelection::CorrelationCoverTree,
        seed,
        ..Default::default()
    };
    let init_kernel = ArdMatern::isotropic(1.0, 0.5, d, smoothness);
    let t0 = std::time::Instant::now();
    // Fit, snapshot, and keep the writer-side model for ingest.
    enum Writer {
        Gaussian(VifRegression),
        Laplace(VifLaplaceModel),
    }
    let (snapshot, mut writer): (Arc<dyn ServeModel>, Writer) = match lik {
        Likelihood::Gaussian { .. } => {
            let init = GaussianParams { kernel: init_kernel, noise: 0.2 };
            let mut model = match VifRegression::try_new(xtr, ytr, config, init) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("invalid training data: {e}");
                    return 2;
                }
            };
            let nll = model.fit(iters);
            println!("fit done in {:.1}s  NLL {:.3}", t0.elapsed().as_secs_f64(), nll);
            (Arc::new(model.snapshot()), Writer::Gaussian(model))
        }
        _ => {
            let mode = SolveMode::Iterative(IterConfig { seed, ..Default::default() });
            let mut model =
                match VifLaplaceModel::try_new(xtr, ytr, config, mode, init_kernel, lik) {
                    Ok(m) => m,
                    Err(e) => {
                        eprintln!("invalid training data: {e}");
                        return 2;
                    }
                };
            let nll = model.fit(iters);
            println!("fit done in {:.1}s  L^VIFLA {:.3}", t0.elapsed().as_secs_f64(), nll);
            if model.state.is_none() {
                model.refresh_state();
            }
            (Arc::new(model.snapshot()), Writer::Laplace(model))
        }
    };

    let engine = ServeEngine::start(snapshot, opts.clone());
    println!(
        "serving generation {} (max_batch {}, batch_window {:?}, {} clients, {} requests)",
        engine.current_generation(),
        opts.max_batch,
        opts.batch_window,
        concurrency,
        requests
    );
    let served = std::sync::atomic::AtomicUsize::new(0);
    let t1 = std::time::Instant::now();
    std::thread::scope(|scope| {
        let engine = &engine;
        let served = &served;
        let qpool = &qpool;
        // Client threads: round-robin over the query pool.
        for t in 0..concurrency {
            scope.spawn(move || {
                let mut i = t;
                while i < requests {
                    let row = qpool.row(i % qpool.rows());
                    if let Err(e) = engine.predict(row) {
                        eprintln!("request failed: {e}");
                    }
                    served.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    i += concurrency;
                }
            });
        }
        // Writer: ingest + publish new generations under traffic.
        if append_every > 0 {
            scope.spawn(move || {
                let mut appended = 0usize;
                loop {
                    let done = served.load(std::sync::atomic::Ordering::Relaxed);
                    if done >= requests {
                        break;
                    }
                    if done / append_every > appended {
                        appended = done / append_every;
                        let lo = (appended * append_batch) % qpool.rows();
                        let take = append_batch.min(qpool.rows() - lo);
                        let xa = vifgp::Mat::from_fn(take, d, |i, j| qpool.get(lo + i, j));
                        let ya: Vec<f64> = (0..take).map(|i| qresp[lo + i]).collect();
                        let generation = match &mut writer {
                            Writer::Gaussian(mdl) => {
                                mdl.append_points(&xa, &ya).expect("append failed");
                                engine.publish(Arc::new(mdl.snapshot()))
                            }
                            Writer::Laplace(mdl) => {
                                mdl.append_points(&xa, &ya).expect("append failed");
                                mdl.refresh_state();
                                engine.publish(Arc::new(mdl.snapshot()))
                            }
                        };
                        println!("published generation {generation} (+{take} points)");
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            });
        }
    });
    let wall = t1.elapsed().as_secs_f64();
    engine.shutdown();
    let report = engine.metrics().report();
    println!(
        "served {} requests in {:.2}s: p50 {:.0}µs  p99 {:.0}µs  {:.0} points/sec  \
         mean batch {:.1}",
        report.requests,
        wall,
        report.p50_latency_us,
        report.p99_latency_us,
        report.points_per_sec,
        report.mean_batch
    );
    println!(
        "health: {}  (panics {}, quarantined {}, deadline-shed {}, non-finite {})",
        match report.health {
            vifgp::serve::Health::Healthy => "healthy",
            vifgp::serve::Health::Degraded => "DEGRADED",
        },
        report.panics_caught,
        report.quarantined_requests,
        report.deadline_expired,
        report.nonfinite_replies
    );
    if let Ok(path) = std::env::var("VIFGP_SERVE_METRICS_JSON") {
        if let Err(e) = std::fs::write(&path, report.to_json()) {
            eprintln!("metrics write failed ({path}): {e}");
            return 1;
        }
        println!("metrics written to {path}");
    }
    0
}

fn cmd_experiment(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("experiment NAME required; see rust/benches/");
        return 2;
    };
    eprintln!(
        "experiment `{name}` is served by the bench harnesses: run\n  cargo bench --bench {name}_*\nor see rust/benches/ for the full list."
    );
    0
}
