//! `vifgp` — command-line entry point for the VIF Gaussian-process
//! library (Layer-3 leader binary).
//!
//! Subcommands (hand-rolled parser; no clap in the offline registry):
//!
//! ```text
//! vifgp info
//! vifgp simulate --n 5000 --d 2 [--smoothness 1.5] [--likelihood gaussian]
//!                [--seed 0] --out data.csv
//! vifgp train    --data data.csv [--m 200] [--mv 30] [--smoothness 1.5]
//!                [--likelihood gaussian|bernoulli|poisson|gamma|student_t]
//!                [--precond fitc|vifdu|none] [--iters 50] [--test-frac 0.2]
//! vifgp experiment <fig2|fig4|tab1|...>   (thin wrappers over the benches)
//! ```

use std::collections::HashMap;

use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        usage();
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..]);
    if let Err(msg) = apply_runtime_flags(&flags) {
        eprintln!("{msg}");
        std::process::exit(2);
    }
    let code = match cmd.as_str() {
        "info" => cmd_info(),
        "simulate" => cmd_simulate(&flags),
        "train" => cmd_train(&flags),
        "experiment" => cmd_experiment(&args[1..]),
        "help" | "--help" | "-h" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown command `{other}`");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "vifgp — Vecchia-inducing-points full-scale GP approximations
USAGE:
  vifgp info
  vifgp simulate --n N --d D [--smoothness S] [--likelihood L] [--seed K] --out FILE
  vifgp train --data FILE [--m M] [--mv MV] [--smoothness S] [--likelihood L]
              [--precond fitc|vifdu|none] [--iters I] [--test-frac F] [--seed K]
  vifgp experiment NAME   (see rust/benches/ for the table/figure harnesses)
GLOBAL FLAGS (any command):
  --threads N           worker-pool size (default: detected parallelism;
                        same as VIFGP_THREADS)
  --sched-threshold N   min rows before Vecchia B sweeps use the level-
                        scheduled parallel path (0 = always; default 2048;
                        same as VIFGP_SCHED_THRESHOLD)"
    );
}

/// Apply the global `--threads` / `--sched-threshold` flags by setting
/// the corresponding environment variables before the worker pool or any
/// residual factor is created.
fn apply_runtime_flags(flags: &HashMap<String, String>) -> Result<(), String> {
    if let Some(t) = flags.get("threads") {
        match t.parse::<usize>() {
            Ok(v) if v >= 1 => std::env::set_var("VIFGP_THREADS", v.to_string()),
            _ => return Err(format!("--threads expects a positive integer, got `{t}`")),
        }
    }
    if let Some(t) = flags.get("sched-threshold") {
        match t.parse::<usize>() {
            Ok(v) => std::env::set_var("VIFGP_SCHED_THRESHOLD", v.to_string()),
            _ => {
                return Err(format!(
                    "--sched-threshold expects a non-negative integer, got `{t}`"
                ))
            }
        }
    }
    Ok(())
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse::<T>().ok())
        .unwrap_or(default)
}

fn parse_likelihood(flags: &HashMap<String, String>) -> Likelihood {
    match flags.get("likelihood").map(|s| s.as_str()).unwrap_or("gaussian") {
        "gaussian" => Likelihood::Gaussian { variance: 0.1 },
        "bernoulli" | "binary" => Likelihood::BernoulliLogit,
        "poisson" => Likelihood::Poisson,
        "gamma" => Likelihood::Gamma { shape: 2.0 },
        "student_t" | "studentt" => Likelihood::StudentT { scale: 0.2, df: 4.0 },
        other => {
            eprintln!("unknown likelihood `{other}`, using gaussian");
            Likelihood::Gaussian { variance: 0.1 }
        }
    }
}

fn init_runtime() {
    let dir = vifgp::runtime::default_artifact_dir();
    if vifgp::runtime::init_from_artifacts(&dir) {
        eprintln!("[vifgp] PJRT engine loaded from {dir:?}");
    }
}

fn cmd_info() -> i32 {
    println!("vifgp {} — three-layer Rust + JAX + Pallas VIF GP library", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", vifgp::coordinator::num_threads());
    let dir = vifgp::runtime::default_artifact_dir();
    if vifgp::runtime::init_from_artifacts(&dir) {
        let e = vifgp::runtime::engine().unwrap();
        let m = e.manifest();
        println!(
            "PJRT engine: loaded ({:?}; panel {}x{} d_pad {} tile {}x{})",
            dir, m.panel_n, m.panel_m, m.d_pad, m.tile_n, m.tile_m
        );
    } else {
        println!("PJRT engine: unavailable (run `make artifacts`); native covariance path");
    }
    0
}

fn cmd_simulate(flags: &HashMap<String, String>) -> i32 {
    let n: usize = flag(flags, "n", 5000);
    let d: usize = flag(flags, "d", 2);
    let seed: u64 = flag(flags, "seed", 0);
    let smoothness = Smoothness::parse(flags.get("smoothness").map(|s| s.as_str()).unwrap_or("1.5"))
        .unwrap_or(Smoothness::ThreeHalves);
    let lik = parse_likelihood(flags);
    let Some(out) = flags.get("out") else {
        eprintln!("--out FILE required");
        return 2;
    };
    let mut rng = Rng::seed_from(seed);
    let x = data::uniform_inputs(&mut rng, n, d);
    let kernel = ArdMatern::new(1.0, data::paper_length_scales(d, smoothness), smoothness);
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y = data::simulate_response(&mut rng, &latent, &lik);
    match data::save_csv(std::path::Path::new(out), &x, &y) {
        Ok(()) => {
            println!("wrote {n}×{d} (+response) to {out}");
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn cmd_train(flags: &HashMap<String, String>) -> i32 {
    init_runtime();
    let Some(path) = flags.get("data") else {
        eprintln!("--data FILE required");
        return 2;
    };
    let (x, y) = match data::load_csv(std::path::Path::new(path)) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("load failed: {e}");
            return 1;
        }
    };
    let n = x.rows();
    let d = x.cols();
    let seed: u64 = flag(flags, "seed", 0);
    let test_frac: f64 = flag(flags, "test-frac", 0.2);
    let m: usize = flag(flags, "m", 200);
    let mv: usize = flag(flags, "mv", 30);
    let iters: usize = flag(flags, "iters", 50);
    let smoothness = Smoothness::parse(flags.get("smoothness").map(|s| s.as_str()).unwrap_or("1.5"))
        .unwrap_or(Smoothness::ThreeHalves);
    let lik = parse_likelihood(flags);
    let precond_name = flags.get("precond").map(|s| s.as_str()).unwrap_or("fitc");
    let Some(precond) = PrecondType::parse(precond_name) else {
        eprintln!(
            "unknown --precond `{precond_name}`; valid names (case-insensitive): {}",
            PrecondType::VALID_NAMES.join(", ")
        );
        return 2;
    };

    let mut rng = Rng::seed_from(seed);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (tr, te) = data::train_test_split(&mut rng, n, n_test);
    let (xtr, ytr) = (data::subset_rows(&x, &tr), data::subset_vec(&y, &tr));
    let (xte, yte) = (data::subset_rows(&x, &te), data::subset_vec(&y, &te));
    println!("loaded {n}×{d}; train {} / test {}", tr.len(), te.len());

    let config = VifConfig {
        smoothness,
        num_inducing: m.min(xtr.rows()),
        num_neighbors: mv,
        selection: NeighborSelection::CorrelationCoverTree,
        seed,
        ..Default::default()
    };
    let init_kernel = ArdMatern::isotropic(1.0, 0.5, d, smoothness);
    let t0 = std::time::Instant::now();
    match lik {
        Likelihood::Gaussian { .. } => {
            let init = GaussianParams { kernel: init_kernel, noise: 0.2 };
            let mut model = VifRegression::new(xtr, ytr, config, init);
            let nll = model.fit(iters);
            println!("fit done in {:.1}s  NLL {:.3}", t0.elapsed().as_secs_f64(), nll);
            println!(
                "  σ₁² {:.4}  σ² {:.4}  λ {:?}",
                model.params.kernel.variance,
                model.params.noise,
                model
                    .params
                    .kernel
                    .length_scales
                    .iter()
                    .map(|l| (l * 1e4).round() / 1e4)
                    .collect::<Vec<_>>()
            );
            if !yte.is_empty() {
                let (mean, var) = model.predict(&xte);
                println!(
                    "  test RMSE {:.4}  LS {:.4}  CRPS {:.4}",
                    metrics::rmse(&mean, &yte),
                    metrics::log_score_gaussian(&mean, &var, &yte),
                    metrics::crps_gaussian(&mean, &var, &yte)
                );
            }
        }
        _ => {
            let mode = SolveMode::Iterative(IterConfig {
                precond,
                seed,
                ..Default::default()
            });
            let mut model = VifLaplaceModel::new(xtr, ytr, config, mode, init_kernel, lik.clone());
            let nll = model.fit(iters);
            println!("fit done in {:.1}s  L^VIFLA {:.3}", t0.elapsed().as_secs_f64(), nll);
            println!(
                "  σ₁² {:.4}  λ {:?}  ξ {:?}",
                model.kernel.variance,
                model
                    .kernel
                    .length_scales
                    .iter()
                    .map(|l| (l * 1e4).round() / 1e4)
                    .collect::<Vec<_>>(),
                model.lik.pack_aux().iter().map(|a| a.exp()).collect::<Vec<_>>()
            );
            if !yte.is_empty() {
                let pred = model.predict(&xte, PredVarMethod::Sbpv, 100);
                match lik {
                    Likelihood::BernoulliLogit => {
                        let labels: Vec<bool> = yte.iter().map(|&v| v > 0.5).collect();
                        println!(
                            "  test AUC {:.4}  ACC {:.4}  Brier-RMSE {:.4}",
                            metrics::auc(&pred.response_mean, &labels),
                            metrics::accuracy(&pred.response_mean, &labels),
                            metrics::brier_rmse(&pred.response_mean, &labels)
                        );
                    }
                    _ => {
                        println!(
                            "  test RMSE {:.4}  LS {:.4}",
                            metrics::rmse(&pred.response_mean, &yte),
                            model.lik.log_score(&yte, &pred.latent_mean, &pred.latent_var)
                        );
                    }
                }
            }
        }
    }
    0
}

fn cmd_experiment(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("experiment NAME required; see rust/benches/");
        return 2;
    };
    eprintln!(
        "experiment `{name}` is served by the bench harnesses: run\n  cargo bench --bench {name}_*\nor see rust/benches/ for the full list."
    );
    0
}
