//! Flag parsing for the `vifgp` binary.
//!
//! Lives in the library (not `main.rs`) so the malformed-input contract
//! is unit-testable: every parser returns `Result<_, String>` and the
//! binary maps `Err` to "print to stderr, exit 2". The contract —
//! established by `--precond` (PR 1) and `VIFGP_SCHED_THRESHOLD` (PR 6)
//! and now uniform across the whole surface — is that a value that does
//! not parse **never** silently falls back to a default: a typoed
//! `--likelihood` must not quietly train the wrong model, and `--m abc`
//! must not quietly run with `--m 200`.

use std::collections::HashMap;

use crate::kernels::Smoothness;
use crate::likelihoods::Likelihood;

/// Split `--key value` pairs (a bare `--key` becomes `key = "true"`)
/// into a flag map. Positional arguments are ignored.
pub fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Human name of the expected value type, for error messages.
fn type_desc<T: 'static>() -> &'static str {
    use std::any::TypeId;
    let id = TypeId::of::<T>();
    if id == TypeId::of::<usize>() || id == TypeId::of::<u64>() || id == TypeId::of::<u32>() {
        "a non-negative integer"
    } else if id == TypeId::of::<f64>() {
        "a number"
    } else if id == TypeId::of::<bool>() {
        "`true` or `false`"
    } else {
        "a valid value"
    }
}

/// Typed flag lookup: absent → `default`; present but unparseable →
/// `Err` naming the flag, the offending value, and the expected type.
pub fn flag<T: std::str::FromStr + 'static>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse::<T>().map_err(|_| {
            format!("--{key} expects {}, got `{v}`", type_desc::<T>())
        }),
    }
}

/// Spellings [`parse_likelihood`] accepts, for error messages.
pub const VALID_LIKELIHOODS: &[&str] =
    &["gaussian", "bernoulli", "binary", "poisson", "gamma", "student_t", "studentt"];

/// `--likelihood` (default `gaussian`). An unknown name is an error —
/// never a silent Gaussian fallback.
pub fn parse_likelihood(flags: &HashMap<String, String>) -> Result<Likelihood, String> {
    match flags.get("likelihood").map(|s| s.as_str()).unwrap_or("gaussian") {
        "gaussian" => Ok(Likelihood::Gaussian { variance: 0.1 }),
        "bernoulli" | "binary" => Ok(Likelihood::BernoulliLogit),
        "poisson" => Ok(Likelihood::Poisson),
        "gamma" => Ok(Likelihood::Gamma { shape: 2.0 }),
        "student_t" | "studentt" => Ok(Likelihood::StudentT { scale: 0.2, df: 4.0 }),
        other => Err(format!(
            "unknown --likelihood `{other}`; valid names: {}",
            VALID_LIKELIHOODS.join(", ")
        )),
    }
}

/// Spellings [`parse_smoothness`] accepts (any positive number also
/// works), for error messages.
pub const VALID_SMOOTHNESS: &[&str] = &[
    "0.5", "half", "exp", "matern12", "1.5", "matern32", "2.5", "matern52", "inf", "gaussian",
    "rbf", "sqexp",
];

/// `--smoothness` (default `1.5`). A typo is an error — never a silent
/// Matérn-3/2 fallback.
pub fn parse_smoothness(flags: &HashMap<String, String>) -> Result<Smoothness, String> {
    let s = flags.get("smoothness").map(|s| s.as_str()).unwrap_or("1.5");
    Smoothness::parse(s).ok_or_else(|| {
        format!(
            "unknown --smoothness `{s}`; valid names: {} (or any smoothness value ν > 0)",
            VALID_SMOOTHNESS.join(", ")
        )
    })
}

/// `--test-frac` must be finite and in `[0, 1)` — anything else would
/// hand `train_test_split` a nonsense held-out count (NaN rounds to 0,
/// `1.0` leaves an empty training set).
pub fn validate_test_frac(f: f64) -> Result<f64, String> {
    if f.is_finite() && (0.0..1.0).contains(&f) {
        Ok(f)
    } else {
        Err(format!("--test-frac expects a fraction in [0, 1), got `{f}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn parse_flags_pairs_and_booleans() {
        let args: Vec<String> =
            ["--n", "50", "--verbose", "--out", "f.csv"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args);
        assert_eq!(f.get("n").map(String::as_str), Some("50"));
        assert_eq!(f.get("verbose").map(String::as_str), Some("true"));
        assert_eq!(f.get("out").map(String::as_str), Some("f.csv"));
    }

    #[test]
    fn flag_defaults_and_errors() {
        let f = flags(&[("m", "abc"), ("iters", "1e3"), ("test-frac", "20%")]);
        assert_eq!(flag::<usize>(&f, "mv", 30).unwrap(), 30);
        let e = flag::<usize>(&f, "m", 200).unwrap_err();
        assert!(e.contains("--m") && e.contains("`abc`") && e.contains("integer"), "{e}");
        let e = flag::<usize>(&f, "iters", 50).unwrap_err();
        assert!(e.contains("--iters") && e.contains("`1e3`"), "{e}");
        let e = flag::<f64>(&f, "test-frac", 0.2).unwrap_err();
        assert!(e.contains("--test-frac") && e.contains("`20%`") && e.contains("number"), "{e}");
    }

    #[test]
    fn likelihood_and_smoothness_reject_typos() {
        assert!(matches!(
            parse_likelihood(&flags(&[])),
            Ok(Likelihood::Gaussian { .. })
        ));
        assert!(matches!(
            parse_likelihood(&flags(&[("likelihood", "poisson")])),
            Ok(Likelihood::Poisson)
        ));
        let e = parse_likelihood(&flags(&[("likelihood", "gausian")])).unwrap_err();
        assert!(e.contains("gausian") && e.contains("gaussian"), "{e}");

        assert_eq!(parse_smoothness(&flags(&[])).unwrap(), Smoothness::ThreeHalves);
        assert_eq!(
            parse_smoothness(&flags(&[("smoothness", "2.5")])).unwrap(),
            Smoothness::FiveHalves
        );
        let e = parse_smoothness(&flags(&[("smoothness", "matern3/2")])).unwrap_err();
        assert!(e.contains("matern3/2") && e.contains("matern32"), "{e}");
    }

    #[test]
    fn test_frac_bounds() {
        assert_eq!(validate_test_frac(0.0).unwrap(), 0.0);
        assert_eq!(validate_test_frac(0.2).unwrap(), 0.2);
        assert!(validate_test_frac(1.0).is_err());
        assert!(validate_test_frac(-0.1).is_err());
        assert!(validate_test_frac(f64::NAN).is_err());
        assert!(validate_test_frac(f64::INFINITY).is_err());
    }
}
