//! Data generation and workload substrates for the paper's experiments.
//!
//! Covers (§7) simulation from zero-mean GPs with ARD Matérn kernels —
//! exact Cholesky sampling for small n and Vecchia-factor sampling
//! (`y = B⁻¹D^{1/2}z`) for large n — response sampling for every
//! likelihood, the paper's Table-5 length-scale profiles, and the
//! synthetic substitutes for the §8 UCI/OpenML suites (documented in
//! DESIGN.md §Substitutions: no network access in this environment).

use crate::kernels::{ArdMatern, Smoothness};
use crate::likelihoods::{sigmoid, Likelihood};
use crate::linalg::{CholeskyFactor, Mat};
use crate::rng::Rng;
use crate::vecchia::{neighbors, ResidualFactor};
use crate::vif::{CorrelationMetric, VifResidualOracle};

/// Uniform inputs on the unit hypercube (paper §7).
pub fn uniform_inputs(rng: &mut Rng, n: usize, d: usize) -> Mat {
    Mat::from_fn(n, d, |_, _| rng.uniform())
}

/// Clustered anisotropic inputs in [0,1]^d — the real-data substitute
/// profile (real covariate clouds are not uniform).
pub fn clustered_inputs(rng: &mut Rng, n: usize, d: usize, clusters: usize) -> Mat {
    let clusters = clusters.max(1);
    let centers: Vec<Vec<f64>> = (0..clusters)
        .map(|_| (0..d).map(|_| rng.uniform_in(0.15, 0.85)).collect())
        .collect();
    let spreads: Vec<f64> = (0..clusters).map(|_| rng.uniform_in(0.03, 0.18)).collect();
    Mat::from_fn(n, d, |i, j| {
        let c = i % clusters;
        (centers[c][j] + spreads[c] * rng.normal()).clamp(0.0, 1.0)
    })
}

/// Sample a zero-mean latent GP at inputs `x`. Exact for `n ≤ 4000`,
/// Vecchia-factor sampling (`m_v = 40` correlation neighbors) above.
pub fn simulate_latent_gp(rng: &mut Rng, x: &Mat, kernel: &ArdMatern) -> Vec<f64> {
    let n = x.rows();
    if n <= 4000 {
        let mut cov = kernel.sym_cov(x, 0.0);
        cov.add_diag(1e-10 * kernel.variance);
        let chol = CholeskyFactor::new_with_jitter(&cov, 1e-10).expect("sim cov not PD");
        chol.mul_lower(&rng.normal_vec(n))
    } else {
        let oracle = VifResidualOracle {
            kernel,
            x,
            lr: None,
            grad_aux: None,
            extra_params: 0,
            x_panels: None,
        };
        // With no low-rank part the correlation metric reduces to
        // d(i,j) = √(1 − |k_ij/σ₁²|); the batched panel path serves the
        // cover-tree search.
        let metric = CorrelationMetric::new(kernel, x, None);
        let nb = neighbors::covertree_ordered_knn(n, 40, &metric);
        let f = ResidualFactor::build(&oracle, nb, 0.0, 1e-10);
        f.sample(&rng.normal_vec(n))
    }
}

/// Sample responses given latent values, per likelihood.
pub fn simulate_response(rng: &mut Rng, latent: &[f64], lik: &Likelihood) -> Vec<f64> {
    latent
        .iter()
        .map(|&b| match *lik {
            Likelihood::Gaussian { variance } => b + variance.sqrt() * rng.normal(),
            Likelihood::BernoulliLogit => {
                if rng.bernoulli(sigmoid(b)) {
                    1.0
                } else {
                    0.0
                }
            }
            Likelihood::Poisson => rng.poisson(b.exp().min(1e6)) as f64,
            Likelihood::Gamma { shape } => {
                // E[y] = e^b: y = Gamma(shape, scale = e^b / shape)
                rng.gamma(shape) * b.exp() / shape
            }
            Likelihood::StudentT { scale, df } => b + scale * rng.student_t(df),
        })
        .collect()
}

/// Table-5 length-scale profiles: linear interpolation from `lo` to `hi`
/// across the `d` dimensions, with the paper's anchors per (d, ν).
pub fn paper_length_scales(d: usize, smoothness: Smoothness) -> Vec<f64> {
    // (d, lo, hi) anchors; 3/2-Matérn has the full Table-5 row, the other
    // smoothnesses are anchored at d ∈ {2, 10} and follow the 3/2 shape
    // elsewhere (same ratio to the d = 10 anchor).
    let m32: &[(usize, f64, f64)] = &[
        (2, 0.10, 0.22),
        (5, 0.13, 1.5),
        (10, 0.25, 2.2),
        (20, 0.50, 5.5),
        (50, 0.55, 6.0),
        (100, 0.60, 7.0),
    ];
    let anchors: &[(usize, f64, f64)] = match smoothness {
        Smoothness::Half => &[(2, 0.07, 0.30), (10, 0.15, 2.3)],
        Smoothness::FiveHalves => &[(2, 0.12, 0.21), (10, 0.27, 2.1)],
        Smoothness::Gaussian => &[(2, 0.13, 0.19), (10, 0.28, 2.0)],
        _ => m32,
    };
    let lookup = |table: &[(usize, f64, f64)], d: usize| -> Option<(f64, f64)> {
        table.iter().find(|(dd, _, _)| *dd == d).map(|&(_, lo, hi)| (lo, hi))
    };
    let (lo, hi) = match lookup(anchors, d) {
        Some(v) => v,
        None => {
            // scale the 3/2 profile by the ratio at d = 10
            let (l32, h32) = lookup(m32, d)
                .or_else(|| lookup(m32, nearest_anchor(m32, d)))
                .unwrap();
            match lookup(anchors, 10) {
                Some((lo10, hi10)) => {
                    let (l3210, h3210) = lookup(m32, 10).unwrap();
                    (l32 * lo10 / l3210, h32 * hi10 / h3210)
                }
                None => (l32, h32),
            }
        }
    };
    if d == 1 {
        return vec![lo];
    }
    (0..d)
        .map(|k| lo + (hi - lo) * k as f64 / (d - 1) as f64)
        .collect()
}

fn nearest_anchor(table: &[(usize, f64, f64)], d: usize) -> usize {
    table
        .iter()
        .min_by_key(|(dd, _, _)| dd.abs_diff(d))
        .map(|(dd, _, _)| *dd)
        .unwrap()
}

/// Shuffle + split into train/test index sets.
pub fn train_test_split(rng: &mut Rng, n: usize, n_test: usize) -> (Vec<usize>, Vec<usize>) {
    let perm = rng.permutation(n);
    let n_test = n_test.min(n);
    (
        perm[n_test..].to_vec(),
        perm[..n_test].to_vec(),
    )
}

/// k-fold cross-validation index sets: `(train, test)` per fold.
pub fn kfold(rng: &mut Rng, n: usize, k: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    let perm = rng.permutation(n);
    let k = k.max(2).min(n);
    (0..k)
        .map(|f| {
            let lo = n * f / k;
            let hi = n * (f + 1) / k;
            let test: Vec<usize> = perm[lo..hi].to_vec();
            let train: Vec<usize> = perm[..lo].iter().chain(&perm[hi..]).copied().collect();
            (train, test)
        })
        .collect()
}

/// Row subset of a matrix.
pub fn subset_rows(x: &Mat, idx: &[usize]) -> Mat {
    let mut out = Mat::zeros(idx.len(), x.cols());
    for (r, &i) in idx.iter().enumerate() {
        out.row_mut(r).copy_from_slice(x.row(i));
    }
    out
}

/// Element subset of a vector.
pub fn subset_vec(v: &[f64], idx: &[usize]) -> Vec<f64> {
    idx.iter().map(|&i| v[i]).collect()
}

/// Which response family a synthetic suite entry uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SuiteLikelihood {
    Gaussian,
    Bernoulli,
    Poisson,
    Gamma,
    StudentT,
}

/// One entry of the synthetic real-data-substitute suites (§8,
/// DESIGN.md §Substitutions). `n` is scaled down from the paper for the
/// single-core testbed; `d` matches the real data set.
#[derive(Clone, Debug)]
pub struct SuiteSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub lik: SuiteLikelihood,
    /// Base length scale (smaller → rougher surface, like 3dRoad).
    pub length_scale: f64,
    /// Gaussian-noise SD fraction (SNR control) or aux parameter.
    pub noise: f64,
    /// Input clusters (real covariate clouds are lumpy).
    pub clusters: usize,
}

/// Table-1 substitutes: Gaussian-likelihood regression suite.
pub fn regression_suite() -> Vec<SuiteSpec> {
    vec![
        SuiteSpec { name: "3dRoad*", n: 6000, d: 3, lik: SuiteLikelihood::Gaussian, length_scale: 0.05, noise: 0.05, clusters: 1 },
        SuiteSpec { name: "KEGGU*", n: 3000, d: 26, lik: SuiteLikelihood::Gaussian, length_scale: 1.2, noise: 0.10, clusters: 12 },
        SuiteSpec { name: "KEGG*", n: 3000, d: 18, lik: SuiteLikelihood::Gaussian, length_scale: 1.0, noise: 0.10, clusters: 10 },
        SuiteSpec { name: "Elevators*", n: 2500, d: 17, lik: SuiteLikelihood::Gaussian, length_scale: 0.9, noise: 0.35, clusters: 8 },
        SuiteSpec { name: "Protein*", n: 3000, d: 8, lik: SuiteLikelihood::Gaussian, length_scale: 0.25, noise: 0.45, clusters: 6 },
        SuiteSpec { name: "Kin40K*", n: 3000, d: 8, lik: SuiteLikelihood::Gaussian, length_scale: 0.35, noise: 0.08, clusters: 1 },
        SuiteSpec { name: "Ailerons*", n: 2500, d: 33, lik: SuiteLikelihood::Gaussian, length_scale: 1.4, noise: 0.35, clusters: 10 },
    ]
}

/// Table-2 substitutes: binary classification suite.
pub fn binary_suite() -> Vec<SuiteSpec> {
    vec![
        SuiteSpec { name: "Bank*", n: 3000, d: 16, lik: SuiteLikelihood::Bernoulli, length_scale: 0.9, noise: 0.0, clusters: 8 },
        SuiteSpec { name: "Adult*", n: 3000, d: 14, lik: SuiteLikelihood::Bernoulli, length_scale: 0.8, noise: 0.0, clusters: 10 },
        SuiteSpec { name: "Credit*", n: 2500, d: 22, lik: SuiteLikelihood::Bernoulli, length_scale: 1.1, noise: 0.0, clusters: 8 },
        SuiteSpec { name: "MAGIC*", n: 2500, d: 9, lik: SuiteLikelihood::Bernoulli, length_scale: 0.4, noise: 0.0, clusters: 4 },
    ]
}

/// Table-3 substitutes: non-Gaussian regression suite.
pub fn nongaussian_suite() -> Vec<SuiteSpec> {
    vec![
        SuiteSpec { name: "Bike*", n: 2500, d: 12, lik: SuiteLikelihood::Poisson, length_scale: 0.7, noise: 0.0, clusters: 6 },
        SuiteSpec { name: "House*", n: 2500, d: 8, lik: SuiteLikelihood::StudentT, length_scale: 0.4, noise: 0.15, clusters: 6 },
        SuiteSpec { name: "Power*", n: 2500, d: 5, lik: SuiteLikelihood::Gamma, length_scale: 0.3, noise: 0.0, clusters: 3 },
        SuiteSpec { name: "WaterVapor*", n: 3000, d: 2, lik: SuiteLikelihood::Gamma, length_scale: 0.12, noise: 0.0, clusters: 1 },
    ]
}

/// Materialize a suite entry: inputs, responses and the likelihood
/// (with its true auxiliary parameters).
pub fn generate_suite_data(spec: &SuiteSpec, rng: &mut Rng) -> (Mat, Vec<f64>, Likelihood) {
    let x = if spec.clusters <= 1 {
        uniform_inputs(rng, spec.n, spec.d)
    } else {
        clustered_inputs(rng, spec.n, spec.d, spec.clusters)
    };
    // ARD scales spread around the base length scale.
    let ls: Vec<f64> = (0..spec.d)
        .map(|k| spec.length_scale * (1.0 + 1.5 * k as f64 / spec.d.max(1) as f64))
        .collect();
    let kernel = ArdMatern::new(1.0, ls, Smoothness::ThreeHalves);
    let latent = simulate_latent_gp(rng, &x, &kernel);
    let lik = match spec.lik {
        SuiteLikelihood::Gaussian => Likelihood::Gaussian { variance: spec.noise * spec.noise },
        SuiteLikelihood::Bernoulli => Likelihood::BernoulliLogit,
        SuiteLikelihood::Poisson => Likelihood::Poisson,
        SuiteLikelihood::Gamma => Likelihood::Gamma { shape: 2.0 },
        SuiteLikelihood::StudentT => Likelihood::StudentT { scale: spec.noise.max(0.05), df: 4.0 },
    };
    let y = simulate_response(rng, &latent, &lik);
    (x, y, lik)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latent_gp_has_unit_scale() {
        let mut rng = Rng::seed_from(2);
        let x = uniform_inputs(&mut rng, 500, 2);
        let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves);
        let b = simulate_latent_gp(&mut rng, &x, &kernel);
        let var = b.iter().map(|v| v * v).sum::<f64>() / 500.0;
        assert!(var > 0.3 && var < 3.0, "var {var}");
    }

    #[test]
    fn large_n_uses_vecchia_path_and_stays_sane() {
        let mut rng = Rng::seed_from(3);
        let x = uniform_inputs(&mut rng, 4500, 2);
        let kernel = ArdMatern::new(1.0, vec![0.2, 0.2], Smoothness::ThreeHalves);
        let b = simulate_latent_gp(&mut rng, &x, &kernel);
        assert_eq!(b.len(), 4500);
        let var = b.iter().map(|v| v * v).sum::<f64>() / 4500.0;
        assert!(var > 0.3 && var < 3.0, "var {var}");
        // neighboring points should be correlated: sort by first coord
        let mut idx: Vec<usize> = (0..4500).collect();
        idx.sort_by(|&a, &c| x.get(a, 0).total_cmp(&x.get(c, 0)));
        let _ = idx;
    }

    #[test]
    fn kfold_partitions() {
        let mut rng = Rng::seed_from(4);
        let folds = kfold(&mut rng, 103, 5);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 103];
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 103);
            for &t in test {
                seen[t] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn paper_length_scales_shapes() {
        for d in [2usize, 5, 10, 20, 50, 100] {
            let ls = paper_length_scales(d, Smoothness::ThreeHalves);
            assert_eq!(ls.len(), d);
            assert!(ls.windows(2).all(|w| w[1] >= w[0]));
        }
        let l2 = paper_length_scales(2, Smoothness::Gaussian);
        assert!((l2[0] - 0.13).abs() < 1e-12 && (l2[1] - 0.19).abs() < 1e-12);
        // fallback path for ν=1/2, d=50
        let l50 = paper_length_scales(50, Smoothness::Half);
        assert_eq!(l50.len(), 50);
    }

    #[test]
    fn responses_match_likelihood_support() {
        let mut rng = Rng::seed_from(5);
        let latent: Vec<f64> = (0..200).map(|_| rng.normal() * 0.5).collect();
        let bern = simulate_response(&mut rng, &latent, &Likelihood::BernoulliLogit);
        assert!(bern.iter().all(|&y| y == 0.0 || y == 1.0));
        let pois = simulate_response(&mut rng, &latent, &Likelihood::Poisson);
        assert!(pois.iter().all(|&y| y >= 0.0 && y.fract() == 0.0));
        let gam = simulate_response(&mut rng, &latent, &Likelihood::Gamma { shape: 2.0 });
        assert!(gam.iter().all(|&y| y > 0.0));
    }

    #[test]
    fn suites_generate() {
        let mut rng = Rng::seed_from(6);
        for spec in [regression_suite().remove(0), binary_suite().remove(0)] {
            let small = SuiteSpec { n: 200, ..spec };
            let (x, y, _) = generate_suite_data(&small, &mut rng);
            assert_eq!(x.rows(), 200);
            assert_eq!(y.len(), 200);
        }
    }
}

// ---------------------------------------------------------------------
// Minimal CSV I/O (no csv crate offline): last column is the response.
// ---------------------------------------------------------------------

/// Write `(x | y)` as headerless CSV.
pub fn save_csv(path: &std::path::Path, x: &Mat, y: &[f64]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..x.rows() {
        for v in x.row(i) {
            write!(f, "{v},")?;
        }
        writeln!(f, "{}", y[i])?;
    }
    Ok(())
}

/// Read headerless CSV with the response in the last column.
pub fn load_csv(path: &std::path::Path) -> std::io::Result<(Mat, Vec<f64>)> {
    let text = std::fs::read_to_string(path)?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, _> = line.split(',').map(|t| t.trim().parse::<f64>()).collect();
        match vals {
            Ok(v) if v.len() >= 2 => rows.push(v),
            _ => {
                if lineno == 0 {
                    continue; // tolerate a header line
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad csv line {}", lineno + 1),
                ));
            }
        }
    }
    if rows.is_empty() {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "empty csv"));
    }
    let d = rows[0].len() - 1;
    let n = rows.len();
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for (i, r) in rows.iter().enumerate() {
        if r.len() != d + 1 {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "ragged csv"));
        }
        x.row_mut(i).copy_from_slice(&r[..d]);
        y[i] = r[d];
    }
    Ok((x, y))
}

#[cfg(test)]
mod csv_tests {
    use super::*;

    #[test]
    fn csv_round_trip() {
        let mut rng = Rng::seed_from(1);
        let x = uniform_inputs(&mut rng, 20, 3);
        let y: Vec<f64> = (0..20).map(|i| i as f64 * 0.5).collect();
        let path = std::env::temp_dir().join("vifgp_csv_test.csv");
        save_csv(&path, &x, &y).unwrap();
        let (x2, y2) = load_csv(&path).unwrap();
        assert!(x2.max_abs_diff(&x) < 1e-12);
        assert_eq!(y, y2);
        let _ = std::fs::remove_file(&path);
    }
}
