//! Prediction-accuracy metrics used across the paper's experiments (§7–§8):
//! RMSE, Gaussian log-score, CRPS, and the binary-classification metrics
//! (AUC, accuracy, Brier-RMSE, Bernoulli log-score).

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let n = pred.len() as f64;
    (pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / n)
        .sqrt()
}

/// Standard normal pdf.
#[inline]
pub fn phi(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cdf via erf.
#[inline]
pub fn big_phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function via the complementary error function (NR `erfcc`
/// Chebyshev fit, |relative err| < 1.2e-7; adequate for scoring).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Mean Gaussian negative log-score
/// `−1/n Σ log N(y*_i; μ_i, σ_i²)` (paper's LS definition uses the
/// standardized density; this is the standard predictive-density form).
pub fn log_score_gaussian(mu: &[f64], var: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(mu.len(), truth.len());
    assert_eq!(var.len(), truth.len());
    let n = mu.len() as f64;
    mu.iter()
        .zip(var)
        .zip(truth)
        .map(|((m, v), t)| {
            let v = v.max(1e-300);
            0.5 * ((2.0 * std::f64::consts::PI * v).ln() + (t - m) * (t - m) / v)
        })
        .sum::<f64>()
        / n
}

/// Mean continuous ranked probability score for Gaussian predictive
/// distributions (closed form, §7.1).
pub fn crps_gaussian(mu: &[f64], var: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(mu.len(), truth.len());
    let n = mu.len() as f64;
    mu.iter()
        .zip(var)
        .zip(truth)
        .map(|((m, v), t)| {
            let s = v.max(1e-300).sqrt();
            let z = (t - m) / s;
            s * (z * (2.0 * big_phi(z) - 1.0) + 2.0 * phi(z) - 1.0 / std::f64::consts::PI.sqrt())
        })
        .sum::<f64>()
        / n
}

/// Area under the ROC curve (rank statistic with tie handling).
pub fn auc(score: &[f64], label: &[bool]) -> f64 {
    assert_eq!(score.len(), label.len());
    let mut idx: Vec<usize> = (0..score.len()).collect();
    idx.sort_by(|&a, &b| score[a].total_cmp(&score[b]));
    // average ranks with ties
    let mut rank = vec![0.0; score.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && score[idx[j + 1]] == score[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            rank[k] = avg;
        }
        i = j + 1;
    }
    let n_pos = label.iter().filter(|&&l| l).count() as f64;
    let n_neg = label.len() as f64 - n_pos;
    if n_pos == 0.0 || n_neg == 0.0 {
        return f64::NAN;
    }
    let sum_pos: f64 = rank
        .iter()
        .zip(label)
        .filter(|(_, &l)| l)
        .map(|(r, _)| r)
        .sum();
    (sum_pos - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
}

/// Classification accuracy at threshold 0.5.
pub fn accuracy(prob: &[f64], label: &[bool]) -> f64 {
    assert_eq!(prob.len(), label.len());
    let hits = prob
        .iter()
        .zip(label)
        .filter(|(p, &l)| (**p >= 0.5) == l)
        .count();
    hits as f64 / prob.len() as f64
}

/// Square root of the Brier score (paper Table 2's "RMSE").
pub fn brier_rmse(prob: &[f64], label: &[bool]) -> f64 {
    let t: Vec<f64> = label.iter().map(|&l| if l { 1.0 } else { 0.0 }).collect();
    rmse(prob, &t)
}

/// Mean Bernoulli negative log-score.
pub fn log_score_bernoulli(prob: &[f64], label: &[bool]) -> f64 {
    assert_eq!(prob.len(), label.len());
    let n = prob.len() as f64;
    prob.iter()
        .zip(label)
        .map(|(p, &l)| {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            if l {
                -p.ln()
            } else {
                -(1.0 - p).ln()
            }
        })
        .sum::<f64>()
        / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_basic() {
        assert!((rmse(&[1.0, 2.0], &[1.0, 4.0]) - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn erf_reference() {
        // erfcc approximation is accurate to ~1.2e-7 relative.
        assert!(erf(0.0).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 2e-7);
    }

    #[test]
    fn cdf_symmetry() {
        assert!((big_phi(0.0) - 0.5).abs() < 1e-7);
        assert!((big_phi(1.96) - 0.975).abs() < 1e-3);
        assert!((big_phi(1.0) + big_phi(-1.0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn crps_perfect_forecast_small() {
        // tight forecast centered on truth -> tiny CRPS
        let c = crps_gaussian(&[1.0], &[1e-8], &[1.0]);
        assert!(c.abs() < 1e-4);
        // CRPS grows with miss distance
        let far = crps_gaussian(&[0.0], &[1.0], &[3.0]);
        let near = crps_gaussian(&[0.0], &[1.0], &[0.5]);
        assert!(far > near);
    }

    #[test]
    fn log_score_matches_density() {
        let ls = log_score_gaussian(&[0.0], &[1.0], &[0.0]);
        assert!((ls - 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_and_random() {
        let labels = [true, true, false, false];
        assert!((auc(&[0.9, 0.8, 0.2, 0.1], &labels) - 1.0).abs() < 1e-12);
        assert!((auc(&[0.1, 0.2, 0.8, 0.9], &labels)).abs() < 1e-12);
        assert!((auc(&[0.5, 0.5, 0.5, 0.5], &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn accuracy_and_brier() {
        let labels = [true, false, true];
        assert!((accuracy(&[0.9, 0.4, 0.3], &labels) - 2.0 / 3.0).abs() < 1e-12);
        assert!(brier_rmse(&[1.0, 0.0, 1.0], &labels).abs() < 1e-12);
    }

    #[test]
    fn bernoulli_log_score() {
        let ls = log_score_bernoulli(&[0.5, 0.5], &[true, false]);
        assert!((ls - (2.0f64).ln()).abs() < 1e-12);
    }
}
