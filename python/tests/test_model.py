"""Layer-2 checks: exported graph shapes and the AOT round trip."""

import os
import subprocess
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import cov_block_ref


def test_cov_cross_shapes_and_values():
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.uniform(size=(model.PANEL_N, model.D_PAD)))
    zs = jnp.asarray(rng.uniform(size=(model.PANEL_M, model.D_PAD)))
    var = jnp.full((1, 1), 1.3)
    (out,) = model.cov_cross(xs, zs, var, smoothness="gaussian")
    assert out.shape == (model.PANEL_N, model.PANEL_M)
    want = cov_block_ref(xs, zs, jnp.ones(model.D_PAD), 1.3, "gaussian")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-9)


def test_fitc_diag():
    rng = np.random.default_rng(2)
    vt = jnp.asarray(rng.normal(size=(model.PANEL_N, model.PANEL_M)) * 0.01)
    var = jnp.full((1, 1), 2.0)
    (diag,) = model.fitc_diag(vt, var)
    assert diag.shape == (model.PANEL_N,)
    want = 2.0 - np.sum(np.asarray(vt) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(diag), want, rtol=1e-10)


def test_lowering_produces_hlo_text():
    import functools

    from compile.aot import to_hlo_text

    xs, zs, var = model.example_args()
    fn = functools.partial(model.cov_cross, smoothness="half")
    text = to_hlo_text(jax.jit(fn).lower(xs, zs, var))
    assert "HloModule" in text
    assert "f64" in text


def test_aot_cli_writes_artifacts(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        check=True,
        env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    names = sorted(p.name for p in tmp_path.iterdir())
    assert "manifest.txt" in names
    for s in model.SMOOTHNESSES:
        assert f"cov_cross_{s}.hlo.txt" in names
    assert "fitc_diag.hlo.txt" in names
