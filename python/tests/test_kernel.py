"""Layer-1 correctness: Pallas ARD-Matérn kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, length scales and smoothness; this is
the CORE correctness signal for the compile path.
"""

import os

os.environ.setdefault("JAX_ENABLE_X64", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ard_matern import (
    D_PAD,
    TILE_M,
    TILE_N,
    cov_block,
    scale_and_pad,
)
from compile.kernels.ref import cov_block_ref

SMOOTHNESSES = ("half", "three_halves", "five_halves", "gaussian")


def run_pallas(x, z, inv_ls, variance, smoothness, dtype):
    n, m = x.shape[0], z.shape[0]
    n_pad = ((n + TILE_N - 1) // TILE_N) * TILE_N
    m_pad = ((m + TILE_M - 1) // TILE_M) * TILE_M
    xs = scale_and_pad(x, inv_ls, n_pad, dtype=dtype)
    zs = scale_and_pad(z, inv_ls, m_pad, dtype=dtype)
    var = jnp.full((1, 1), variance, dtype=dtype)
    out = cov_block(xs, zs, var, smoothness=smoothness)
    return np.asarray(out)[:n, :m]


@pytest.mark.parametrize("smoothness", SMOOTHNESSES)
def test_matches_ref_basic(smoothness):
    rng = np.random.default_rng(0)
    n, m, d = 100, 37, 3
    x = rng.uniform(size=(n, d))
    z = rng.uniform(size=(m, d))
    inv_ls = np.array([1.0 / 0.3, 1.0 / 0.7, 1.0 / 1.2])
    got = run_pallas(x, z, inv_ls, 1.7, smoothness, jnp.float64)
    want = np.asarray(
        cov_block_ref(jnp.asarray(x), jnp.asarray(z), jnp.asarray(inv_ls), 1.7, smoothness)
    )
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=200),
    m=st.integers(min_value=1, max_value=150),
    d=st.integers(min_value=1, max_value=D_PAD),
    smoothness=st.sampled_from(SMOOTHNESSES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_hypothesis_shapes(n, m, d, smoothness, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(n, d))
    z = rng.uniform(-1.0, 1.0, size=(m, d))
    inv_ls = rng.uniform(0.3, 4.0, size=d)
    variance = float(rng.uniform(0.1, 3.0))
    got = run_pallas(x, z, inv_ls, variance, smoothness, jnp.float64)
    want = np.asarray(
        cov_block_ref(
            jnp.asarray(x), jnp.asarray(z), jnp.asarray(inv_ls), variance, smoothness
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-11)


@settings(max_examples=8, deadline=None)
@given(
    smoothness=st.sampled_from(SMOOTHNESSES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_float32_path(smoothness, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(64, 2))
    z = rng.uniform(size=(48, 2))
    inv_ls = np.array([2.0, 1.5])
    got = run_pallas(
        x.astype(np.float32), z.astype(np.float32), inv_ls, 1.0, smoothness, jnp.float32
    )
    want = np.asarray(
        cov_block_ref(jnp.asarray(x), jnp.asarray(z), jnp.asarray(inv_ls), 1.0, smoothness)
    )
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_diagonal_is_variance():
    rng = np.random.default_rng(3)
    x = rng.uniform(size=(50, 4))
    inv_ls = np.ones(4)
    for smoothness in SMOOTHNESSES:
        got = run_pallas(x, x, inv_ls, 2.5, smoothness, jnp.float64)
        np.testing.assert_allclose(np.diag(got), 2.5, rtol=1e-9)
        # symmetry
        np.testing.assert_allclose(got, got.T, rtol=1e-9, atol=1e-12)


def test_padded_dims_are_inert():
    # Adding zero-weighted padded dims must not change the result.
    rng = np.random.default_rng(5)
    x = rng.uniform(size=(40, 2))
    z = rng.uniform(size=(30, 2))
    inv2 = np.array([1.7, 0.9])
    a = run_pallas(x, z, inv2, 1.0, "three_halves", jnp.float64)
    x8 = np.concatenate([x, rng.uniform(size=(40, 6))], axis=1)
    z8 = np.concatenate([z, rng.uniform(size=(30, 6))], axis=1)
    inv8 = np.concatenate([inv2, np.zeros(6)])
    b = run_pallas(x8, z8, inv8, 1.0, "three_halves", jnp.float64)
    np.testing.assert_allclose(a, b, rtol=1e-12)
