"""AOT driver: lower the Layer-2 graphs to HLO *text* artifacts.

Runs once at build time (``make artifacts``); Python is never on the
Rust request path. HLO text (not ``.serialize()``) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ../artifacts):

    cov_cross_{half,three_halves,five_halves,gaussian}.hlo.txt
    fitc_diag.hlo.txt
    manifest.txt   (shape metadata consumed by rust/src/runtime/)
"""

from __future__ import annotations

import argparse
import functools
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    xs, zs, var = model.example_args()
    written = []

    for smoothness in model.SMOOTHNESSES:
        fn = functools.partial(model.cov_cross, smoothness=smoothness)
        lowered = jax.jit(fn).lower(xs, zs, var)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"cov_cross_{smoothness}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append((os.path.basename(path), len(text)))

    vt = jax.ShapeDtypeStruct((model.PANEL_N, model.PANEL_M), jnp.float64)
    lowered = jax.jit(model.fitc_diag).lower(vt, var)
    path = os.path.join(out_dir, "fitc_diag.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    written.append((os.path.basename(path), os.path.getsize(path)))

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(f"panel_n={model.PANEL_N}\n")
        f.write(f"panel_m={model.PANEL_M}\n")
        f.write(f"d_pad={model.D_PAD}\n")
        f.write(f"tile_n={model.TILE_N}\n")
        f.write(f"tile_m={model.TILE_M}\n")
        f.write("dtype=f64\n")
        for smoothness in model.SMOOTHNESSES:
            f.write(f"artifact=cov_cross_{smoothness}.hlo.txt\n")
        f.write("artifact=fitc_diag.hlo.txt\n")

    for name, size in written:
        print(f"wrote {name} ({size} bytes)")
    print(f"manifest -> {out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
