"""Layer-2 JAX compute graphs for the VIF covariance panels.

These are the functions that get AOT-lowered (by ``aot.py``) into the
HLO artifacts the Rust runtime executes. Each calls the Layer-1 Pallas
kernel so the kernel lowers into the same HLO module. Shapes are fixed
at export time; the Rust side pads inputs to the tile grid and discards
padded rows/columns (see rust/src/runtime/).

Exported graphs (per Matérn smoothness ν ∈ {1/2, 3/2, 5/2, ∞}):

* ``cov_cross``  — (PANEL_N, D_PAD) × (PANEL_M, D_PAD) → (PANEL_N, PANEL_M)
  cross-covariance panel (the Σ_mn / prediction hot path);
* ``fitc_diag``  — the FITC/residual diagonal correction
  ``σ₁² − Σᵢ (L_m⁻¹ k_i)²`` given a pre-solved panel, fused with the
  covariance evaluation on the low-rank path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.ard_matern import D_PAD, TILE_M, TILE_N, cov_block

# Panel shape exported to artifacts (Rust pads to these).
PANEL_N = 512
PANEL_M = 256

SMOOTHNESSES = ("half", "three_halves", "five_halves", "gaussian")


def cov_cross(xs, zs, variance, *, smoothness: str):
    """Cross-covariance panel; inputs pre-scaled by 1/λ and padded."""
    return (cov_block(xs, zs, variance, smoothness=smoothness),)


def fitc_diag(vt_panel, variance):
    """Residual diagonal ``σ₁² − ‖v_i‖²`` for a solved panel
    ``vt_panel = (L_m⁻¹ Σ_m·)ᵀ`` (PANEL_N, PANEL_M-capped rank)."""
    return (variance[0, 0] - jnp.sum(vt_panel * vt_panel, axis=1),)


def example_args(dtype=jnp.float64):
    import jax

    xs = jax.ShapeDtypeStruct((PANEL_N, D_PAD), dtype)
    zs = jax.ShapeDtypeStruct((PANEL_M, D_PAD), dtype)
    var = jax.ShapeDtypeStruct((1, 1), dtype)
    return xs, zs, var


__all__ = [
    "cov_cross",
    "fitc_diag",
    "example_args",
    "PANEL_N",
    "PANEL_M",
    "D_PAD",
    "TILE_N",
    "TILE_M",
    "SMOOTHNESSES",
]
