"""Pure-jnp oracle for the Pallas ARD-Matérn kernel.

This is the correctness reference (no tiling, no distance-expansion
tricks): direct pairwise scaled distances and the closed-form Matérn
profiles. ``python/tests/test_kernel.py`` asserts the Pallas kernel
matches this to float tolerance across shapes and dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp

SQRT3 = 1.7320508075688772
SQRT5 = 2.23606797749979


def radial_profile_ref(r, smoothness: str):
    if smoothness == "half":
        return jnp.exp(-r)
    if smoothness == "three_halves":
        t = SQRT3 * r
        return (1.0 + t) * jnp.exp(-t)
    if smoothness == "five_halves":
        t = SQRT5 * r
        return (1.0 + t + t * t / 3.0) * jnp.exp(-t)
    if smoothness == "gaussian":
        return jnp.exp(-0.5 * r * r)
    raise ValueError(f"unknown smoothness {smoothness!r}")


def cov_block_ref(x, z, inv_length_scales, variance, smoothness: str):
    """Direct cross-covariance: x (n, d), z (m, d), 1/λ (d,)."""
    xs = x * inv_length_scales[None, :]
    zs = z * inv_length_scales[None, :]
    diff = xs[:, None, :] - zs[None, :, :]
    r = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    return variance * radial_profile_ref(r, smoothness)
