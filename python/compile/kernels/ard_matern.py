"""Layer-1 Pallas kernel: tiled ARD-Matérn cross-covariance blocks.

The compute hot-spot of the VIF approximation is evaluating covariance
panels ``Σ_mn = c_θ(X, Z)`` (paper §2.1): every likelihood evaluation
builds an n×m cross-covariance plus n·m_v² residual blocks. This kernel
computes one ``(TILE_N, TILE_M)`` block of the ARD-Matérn cross-covariance

    k(x, z) = σ₁² · k_ν(‖(x − z) / λ‖)

mapped to TPU idioms (DESIGN.md §Hardware-Adaptation):

* the scaled squared distance uses the ‖a−b‖² = ‖a‖² + ‖b‖² − 2a·b
  expansion, so the cross term is a single (TILE_N, D_PAD)×(D_PAD, TILE_M)
  matmul that targets the MXU;
* inputs are pre-scaled by 1/λ and feature-padded to ``D_PAD`` with zero
  inverse length scales (a padded coordinate contributes nothing);
* the elementwise Matérn radial profile runs on the VPU;
* ``BlockSpec`` tiles the (N, M) output over a 2-D grid so each block's
  VMEM footprint is 2·TILE·D_PAD + TILE² floats.

The kernel MUST run with ``interpret=True`` on this image (CPU PJRT
cannot execute Mosaic custom-calls); real-TPU performance is estimated
analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tiling constants shared with the Rust runtime (rust/src/runtime/mod.rs).
TILE_N = 128
TILE_M = 128
D_PAD = 8

SQRT3 = 1.7320508075688772
SQRT5 = 2.23606797749979


def _radial_profile(r, smoothness: str):
    """Matérn correlation k_ν(r) with k(0) = 1 (static smoothness)."""
    if smoothness == "half":
        return jnp.exp(-r)
    if smoothness == "three_halves":
        t = SQRT3 * r
        return (1.0 + t) * jnp.exp(-t)
    if smoothness == "five_halves":
        t = SQRT5 * r
        return (1.0 + t + t * t / 3.0) * jnp.exp(-t)
    if smoothness == "gaussian":
        return jnp.exp(-0.5 * r * r)
    raise ValueError(f"unknown smoothness {smoothness!r}")


def _cov_block_kernel(xs_ref, zs_ref, var_ref, out_ref, *, smoothness: str):
    """One (TILE_N, TILE_M) covariance block.

    ``xs_ref``/``zs_ref`` hold 1/λ-scaled coordinates; ``var_ref`` is a
    (1, 1) block holding σ₁².
    """
    xs = xs_ref[...]  # (TILE_N, D_PAD), already scaled by 1/λ
    zs = zs_ref[...]  # (TILE_M, D_PAD)
    # MXU-mapped cross term + VPU norms.
    xn = jnp.sum(xs * xs, axis=1, keepdims=True)          # (TILE_N, 1)
    zn = jnp.sum(zs * zs, axis=1, keepdims=True).T        # (1, TILE_M)
    cross = jax.lax.dot_general(
        xs, zs, (((1,), (1,)), ((), ())),
        preferred_element_type=xs.dtype,
    )                                                      # (TILE_N, TILE_M)
    r2 = jnp.maximum(xn + zn - 2.0 * cross, 0.0)
    r = jnp.sqrt(r2)
    out_ref[...] = var_ref[0, 0] * _radial_profile(r, smoothness)


@functools.partial(jax.jit, static_argnames=("smoothness",))
def cov_block(xs, zs, variance, *, smoothness: str):
    """Cross-covariance of pre-scaled points via the Pallas kernel.

    ``xs``: (N, D_PAD), ``zs``: (M, D_PAD) with N, M multiples of the tile
    sizes; ``variance``: scalar σ₁² as shape (1, 1).
    """
    n, d = xs.shape
    m, d2 = zs.shape
    assert d == D_PAD and d2 == D_PAD, f"feature dim must be padded to {D_PAD}"
    assert n % TILE_N == 0 and m % TILE_M == 0, "pad N, M to tile multiples"
    grid = (n // TILE_N, m // TILE_M)
    return pl.pallas_call(
        functools.partial(_cov_block_kernel, smoothness=smoothness),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, D_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_M, D_PAD), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, TILE_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), xs.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xs, zs, variance)


def scale_and_pad(x, inv_length_scales, rows, dtype=jnp.float64):
    """Host-side helper mirroring the Rust runtime's pad-and-mask step."""
    import numpy as np

    n, d = x.shape
    assert d <= D_PAD
    out = np.zeros((rows, D_PAD), dtype=dtype)
    out[:n, :d] = np.asarray(x) * np.asarray(inv_length_scales)[None, :]
    return jnp.asarray(out)
